//! Wire-protocol properties and the loopback serving tier end to end:
//! seeded random frames round-trip bit-identically (the canonical
//! encoding the differential transport suite relies on), corrupt frames
//! are typed rejections that poison only their own connection, and a
//! listener under live load drains gracefully — every admitted request
//! answered exactly once, late connects refused at the OS level.

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

use morpho::coordinator::request::RequestTiming;
use morpho::coordinator::wire::{self, ERR_MALFORMED, ERR_UNEXPECTED_KIND};
use morpho::coordinator::{
    BackendChoice, BackendKind, BatcherConfig, Coordinator, CoordinatorConfig, Frame, HealthStats,
    Priority, RejectReason, Rejection, ServeResult, TransformRequest, TransformResponse,
    WireError, WireServer, MAX_FRAME, WIRE_VERSION,
};
use morpho::graphics::Transform;
use morpho::loadgen::WireClient;
use morpho::testkit::{check, Rng};

// ── generators ─────────────────────────────────────────────────────────

fn random_transform(rng: &mut Rng) -> Transform {
    match rng.below(4) {
        0 => Transform::Translate {
            tx: rng.f32_range(-100.0, 100.0),
            ty: rng.f32_range(-100.0, 100.0),
        },
        1 => Transform::Scale { sx: rng.f32_range(-2.0, 2.0), sy: rng.f32_range(-2.0, 2.0) },
        2 => Transform::Rotate { theta: rng.f32_range(-3.2, 3.2) },
        _ => Transform::RotateAbout {
            theta: rng.f32_range(-3.2, 3.2),
            cx: rng.f32_range(-50.0, 50.0),
            cy: rng.f32_range(-50.0, 50.0),
        },
    }
}

fn random_request(rng: &mut Rng) -> TransformRequest {
    let n = rng.below(65) as usize;
    TransformRequest {
        id: rng.next_u64(),
        xs: (0..n).map(|_| rng.f32_range(-1e4, 1e4)).collect(),
        ys: (0..n).map(|_| rng.f32_range(-1e4, 1e4)).collect(),
        transforms: (0..rng.below(5)).map(|_| random_transform(rng)).collect(),
        ttl: if rng.bool() { Some(Duration::from_nanos(rng.next_u64())) } else { None },
        priority: if rng.bool() { Priority::Bulk } else { Priority::Interactive },
    }
}

fn random_result(rng: &mut Rng) -> ServeResult {
    if rng.bool() {
        let n = rng.below(33) as usize;
        Ok(TransformResponse {
            id: rng.next_u64(),
            xs: (0..n).map(|_| rng.f32_range(-1e4, 1e4)).collect(),
            ys: (0..n).map(|_| rng.f32_range(-1e4, 1e4)).collect(),
            timing: RequestTiming {
                queued: Duration::from_nanos(rng.next_u64()),
                execute: Duration::from_nanos(rng.next_u64()),
                backend: match rng.below(3) {
                    0 => BackendKind::Native,
                    1 => BackendKind::Xla,
                    _ => BackendKind::M1Sim,
                },
                simulated_cycles: if rng.bool() { Some(rng.next_u64()) } else { None },
            },
        })
    } else {
        Err(Rejection {
            id: rng.next_u64(),
            reason: match rng.below(3) {
                0 => RejectReason::QueueFull,
                1 => RejectReason::DeadlineExceeded,
                _ => RejectReason::ShuttingDown,
            },
        })
    }
}

fn random_health(rng: &mut Rng) -> (u64, HealthStats) {
    let seq = rng.next_u64();
    let stats = HealthStats {
        queue_depth: rng.next_u64(),
        requests: rng.next_u64(),
        responses: rng.next_u64(),
        shed: rng.next_u64(),
        rejected: rng.next_u64(),
        closed: rng.next_u64(),
        deadline_missed: rng.next_u64(),
        shard_crashes: rng.next_u64(),
        shard_restarts: rng.next_u64(),
        tiles_redispatched: rng.next_u64(),
        recovery_max_us: rng.next_u64(),
    };
    (seq, stats)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ── properties ─────────────────────────────────────────────────────────

/// Seeded random requests and results survive encode → frame → decode
/// with every `f32` bit pattern intact, and re-encoding the decoded
/// frame reproduces the original wire bytes exactly.
#[test]
fn seeded_random_frames_roundtrip_bit_identically() {
    check("wire roundtrip", 200, |rng| {
        let req = random_request(rng);
        let fast = rng.bool();
        let bytes = wire::encode_request(&req, fast);
        let payload = wire::read_frame(&mut &bytes[..]).unwrap().unwrap();
        let frame = wire::decode_frame(&payload).unwrap();
        assert_eq!(wire::encode_frame(&frame), bytes, "request re-encode is bit-identical");
        match frame {
            Frame::Request { req: back, fast_reject } => {
                assert_eq!(fast_reject, fast);
                assert_eq!(back.id, req.id);
                assert_eq!(back.ttl, req.ttl);
                assert_eq!(back.priority, req.priority);
                assert_eq!(back.transforms, req.transforms);
                assert_eq!(bits(&back.xs), bits(&req.xs));
                assert_eq!(bits(&back.ys), bits(&req.ys));
            }
            other => panic!("expected request frame, got {other:?}"),
        }

        let res = random_result(rng);
        let bytes = wire::encode_result(&res);
        let payload = wire::read_frame(&mut &bytes[..]).unwrap().unwrap();
        let frame = wire::decode_frame(&payload).unwrap();
        assert_eq!(wire::encode_frame(&frame), bytes, "result re-encode is bit-identical");
        match (frame, res) {
            (Frame::Result(Ok(b)), Ok(a)) => {
                assert_eq!(b.id, a.id);
                assert_eq!(b.timing.queued, a.timing.queued);
                assert_eq!(b.timing.execute, a.timing.execute);
                assert_eq!(b.timing.backend, a.timing.backend);
                assert_eq!(b.timing.simulated_cycles, a.timing.simulated_cycles);
                assert_eq!(bits(&b.xs), bits(&a.xs));
                assert_eq!(bits(&b.ys), bits(&a.ys));
            }
            (Frame::Result(Err(b)), Err(a)) => assert_eq!(a, b),
            (frame, res) => panic!("variant flipped in transit: {frame:?} vs {res:?}"),
        }

        // Kind-5 health: polls (empty body) and full-entropy reports
        // round-trip under the same canonical-encoding contract.
        let (seq, stats) = random_health(rng);
        let stats = rng.bool().then_some(stats);
        let bytes = wire::encode_health(seq, stats.as_ref());
        let payload = wire::read_frame(&mut &bytes[..]).unwrap().unwrap();
        let frame = wire::decode_frame(&payload).unwrap();
        assert_eq!(wire::encode_frame(&frame), bytes, "health re-encode is bit-identical");
        match frame {
            Frame::Health { seq: back_seq, stats: back } => {
                assert_eq!(back_seq, seq);
                assert_eq!(back, stats);
            }
            other => panic!("expected health frame, got {other:?}"),
        }
    });
}

/// Corruption can't alias: flipping any single bit of a valid payload
/// either fails to decode (a typed [`WireError`]) or decodes to a frame
/// whose canonical re-encoding *is* the flipped byte string — never a
/// second encoding of the original frame.
#[test]
fn every_bit_flip_fails_decode_or_reencodes_to_the_flipped_bytes() {
    let mut rng = Rng::new(0x51DE_CA11);
    let mut frames: Vec<Vec<u8>> = vec![
        wire::encode_protocol_error(ERR_MALFORMED, "truncated frame (payload)"),
        wire::encode_result(&Err(Rejection { id: 3, reason: RejectReason::QueueFull })),
    ];
    for _ in 0..3 {
        let mut req = random_request(&mut rng);
        req.xs.truncate(8); // keep the flip sweep cheap
        req.ys.truncate(8);
        frames.push(wire::encode_request(&req, rng.bool()));
        frames.push(wire::encode_result(&random_result(&mut rng)));
    }
    // Health frames obey the same no-alias discipline: a poll and a
    // full-entropy report (flips in the tag, seq or any counter either
    // fail typed or re-encode to exactly the flipped bytes).
    let (seq, report) = random_health(&mut rng);
    frames.push(wire::encode_health(seq, None));
    frames.push(wire::encode_health(seq, Some(&report)));
    for bytes in frames {
        let payload = wire::read_frame(&mut &bytes[..]).unwrap().unwrap();
        for bit in 0..payload.len() * 8 {
            let mut flipped = payload.clone();
            flipped[bit / 8] ^= 1 << (bit % 8);
            if let Ok(frame) = wire::decode_frame(&flipped) {
                let mut expect = (flipped.len() as u32).to_le_bytes().to_vec();
                expect.extend_from_slice(&flipped);
                assert_eq!(
                    wire::encode_frame(&frame),
                    expect,
                    "bit {bit} decoded to a non-canonical alias"
                );
            }
        }
    }
}

/// Frame-layer stream handling: the only clean EOF is at a frame
/// boundary; every mid-frame cut is a typed truncation, and an absurd
/// length prefix is refused before any allocation happens.
#[test]
fn truncated_and_oversized_streams_are_rejected_at_the_frame_layer() {
    let req = TransformRequest::new(
        9,
        vec![1.0, 2.0, 3.0],
        vec![4.0, 5.0, 6.0],
        vec![Transform::Rotate { theta: 1.25 }],
    );
    let bytes = wire::encode_request(&req, false);
    for cut in 0..bytes.len() {
        match wire::read_frame(&mut &bytes[..cut]) {
            Ok(None) => assert_eq!(cut, 0, "only the empty stream is a clean EOF"),
            Err(WireError::Truncated { .. }) => assert!(cut > 0),
            other => panic!("cut at {cut}: expected truncation, got {other:?}"),
        }
    }
    // Same sweep over a kind-5 health report: every prefix of the frame
    // is a typed truncation at the stream layer, never a short decode.
    let report = HealthStats {
        queue_depth: 2,
        requests: 9,
        responses: 7,
        recovery_max_us: 450,
        ..Default::default()
    };
    let bytes = wire::encode_health(21, Some(&report));
    for cut in 0..bytes.len() {
        match wire::read_frame(&mut &bytes[..cut]) {
            Ok(None) => assert_eq!(cut, 0, "only the empty stream is a clean EOF"),
            Err(WireError::Truncated { .. }) => assert!(cut > 0),
            other => panic!("health cut at {cut}: expected truncation, got {other:?}"),
        }
    }
    let mut huge = u32::MAX.to_le_bytes().to_vec();
    huge.extend_from_slice(&[0u8; 8]);
    assert!((u32::MAX as usize) > MAX_FRAME);
    match wire::read_frame(&mut &huge[..]) {
        Err(WireError::Oversized { announced }) => assert_eq!(announced, u32::MAX as usize),
        other => panic!("expected oversized, got {other:?}"),
    }
}

// ── the loopback serving tier ──────────────────────────────────────────

fn native_coordinator() -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendChoice::Native,
            workers: 2,
            batcher: BatcherConfig { max_wait: Duration::from_micros(200), ..Default::default() },
            ..Default::default()
        })
        .unwrap(),
    )
}

/// One served round-trip with an exactly-predictable answer (small
/// integer translate: every f32 op is exact).
fn serve_one(client: &WireClient) {
    let rx = client
        .submit(
            vec![1.0, 2.0],
            vec![10.0, 20.0],
            vec![Transform::Translate { tx: 1.0, ty: -1.0 }],
            false,
        )
        .expect("submit over live connection");
    let resp = rx.recv_timeout(Duration::from_secs(10)).expect("reply").expect("served");
    assert_eq!(resp.xs, vec![2.0, 3.0]);
    assert_eq!(resp.ys, vec![9.0, 19.0]);
}

fn length_prefixed(payload: &[u8]) -> Vec<u8> {
    let mut out = (payload.len() as u32).to_le_bytes().to_vec();
    out.extend_from_slice(payload);
    out
}

/// Read the server's answer to a malformed/forbidden frame: exactly one
/// ProtocolError frame with the expected code, then EOF — the server
/// closed this connection and nothing else.
fn expect_protocol_error_then_eof(stream: &mut TcpStream, code: u8) {
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let payload = wire::read_frame(stream)
        .expect("the error report frame arrives before the close")
        .expect("error report, not bare EOF");
    match wire::decode_frame(&payload).unwrap() {
        Frame::ProtocolError { code: got, message } => {
            assert_eq!(got, code, "error code (message: {message})");
            assert!(!message.is_empty(), "the error report names the problem");
        }
        other => panic!("expected a protocol error frame, got {other:?}"),
    }
    assert!(
        wire::read_frame(stream).unwrap().is_none(),
        "the connection must close right after the error frame"
    );
}

/// A connection sending garbage gets a typed ProtocolError and is
/// dropped — while the listener and every *other* connection keep
/// serving untouched, for each of the malformed-input classes.
#[test]
fn malformed_frames_poison_only_their_own_connection() {
    let c = native_coordinator();
    let server = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
    let addr = server.local_addr();

    let good = WireClient::connect(addr, None).unwrap();
    serve_one(&good);

    let malformed: Vec<(&str, Vec<u8>, u8)> = vec![
        ("unknown version", length_prefixed(&[WIRE_VERSION + 1, 1]), ERR_MALFORMED),
        ("unknown kind", length_prefixed(&[WIRE_VERSION, 99]), ERR_MALFORMED),
        (
            "oversized announcement",
            ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec(),
            ERR_MALFORMED,
        ),
        (
            // A server-only frame kind from a client: well-formed, still fatal.
            "unexpected kind",
            wire::encode_result(&Err(Rejection { id: 1, reason: RejectReason::QueueFull })),
            ERR_UNEXPECTED_KIND,
        ),
    ];
    for (what, bytes, code) in malformed {
        let mut bad = TcpStream::connect(addr).expect(what);
        bad.write_all(&bytes).unwrap();
        expect_protocol_error_then_eof(&mut bad, code);
        // The listener and the established connection shrug it off.
        serve_one(&good);
    }

    // A frame cut off mid-payload by a half-close is a truncation, not a
    // hang: the reader reports it and closes.
    let mut bad = TcpStream::connect(addr).unwrap();
    let mut partial = 64u32.to_le_bytes().to_vec();
    partial.extend_from_slice(&[7u8; 8]);
    bad.write_all(&partial).unwrap();
    bad.shutdown(Shutdown::Write).unwrap();
    expect_protocol_error_then_eof(&mut bad, ERR_MALFORMED);
    serve_one(&good);

    // Fresh connections are still welcome after all that abuse.
    let late = WireClient::connect(addr, None).unwrap();
    serve_one(&late);

    drop(good);
    drop(late);
    server.shutdown();
    if let Ok(c) = Arc::try_unwrap(c) {
        c.shutdown();
    }
}

/// Graceful drain under live load: shutting the server down mid-run
/// stops the listener (late connects refused at the OS level, accept
/// thread joined), answers every admitted request exactly once, and
/// turns requests racing the close into explicit ShuttingDown
/// rejections — never silence.
#[test]
fn graceful_drain_under_load_answers_every_admitted_request() {
    let c = native_coordinator();
    let server = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
    let addr = server.local_addr();

    // Three closed-loop connections hammer the server until the drain
    // tears their sockets down.
    let drivers: Vec<_> = (0..3u64)
        .map(|t| {
            std::thread::spawn(move || -> (u64, u64, u64) {
                let client = WireClient::connect(addr, None).expect("connect before drain");
                let (mut completed, mut rejected, mut unread) = (0u64, 0u64, 0u64);
                for i in 0u64.. {
                    let xs = vec![((t * 1009 + i) % 97) as f32; 16];
                    let ys = vec![0.5f32; 16];
                    let tf = vec![Transform::Translate { tx: 2.0, ty: 1.0 }];
                    let rx = match client.submit(xs, ys, tf, false) {
                        Ok(rx) => rx,
                        Err(_) => break, // connection torn down: drained
                    };
                    match rx.recv_timeout(Duration::from_secs(30)) {
                        Ok(Ok(_)) => completed += 1,
                        Ok(Err(rej)) => {
                            assert_eq!(rej.reason, RejectReason::ShuttingDown);
                            rejected += 1;
                        }
                        // Written but never read by the closing server:
                        // never admitted, observed as a disconnect.
                        Err(RecvTimeoutError::Disconnected) => {
                            unread += 1;
                            break;
                        }
                        Err(RecvTimeoutError::Timeout) => {
                            panic!("request neither answered nor disconnected")
                        }
                    }
                }
                (completed, rejected, unread)
            })
        })
        .collect();

    // Meanwhile a pipelined client floods 16 requests before reading any
    // reply — the demux must hand each receiver its *own* answer.
    let pipelined = WireClient::connect(addr, None).unwrap();
    let handles: Vec<_> = (0..16u32)
        .map(|i| {
            let n = 8 + (i as usize % 5) * 7;
            pipelined
                .submit(
                    vec![i as f32; n],
                    vec![1.0; n],
                    vec![Transform::Scale { sx: 1.5, sy: 0.5 }],
                    false,
                )
                .unwrap()
        })
        .collect();
    for (i, rx) in handles.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("pipelined reply").expect("ok");
        assert_eq!(resp.xs.len(), 8 + (i % 5) * 7);
        assert_eq!(
            resp.xs[0].to_bits(),
            (i as f32 * 1.5).to_bits(),
            "request {i} must get its own answer back"
        );
    }
    drop(pipelined);

    std::thread::sleep(Duration::from_millis(30));
    server.shutdown(); // blocks until everything admitted is answered

    // The listener is gone (and with it the accept thread — shutdown()
    // joins it, so returning at all proves no leak).
    assert!(TcpStream::connect(addr).is_err(), "late connects must be refused");

    let (mut completed, mut rejected, mut unread) = (0u64, 0u64, 0u64);
    for d in drivers {
        let (c2, r, u) = d.join().unwrap();
        completed += c2;
        rejected += r;
        unread += u;
    }
    assert!(completed > 0, "the load must actually be served before the drain");

    // The server-side ledger: without TTLs nothing sheds, so exactly-one
    // -reply means answered == admitted; door rejections and unread
    // frames were never admitted at all.
    let m = c.metrics();
    assert_eq!(
        m.responses, m.requests,
        "every admitted request answered (rejected={rejected} unread={unread})"
    );
    assert_eq!(m.shed, 0);
    assert!(m.responses >= completed, "clients can't have seen more than was sent");
    if let Ok(c) = Arc::try_unwrap(c) {
        c.shutdown();
    }
}
