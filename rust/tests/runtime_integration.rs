//! Integration: the PJRT runtime loads every AOT artifact, executes it,
//! and agrees with the native rust reference path. Requires the `pjrt`
//! feature (the offline default builds the stub runtime) plus
//! `make artifacts`; with both present these tests fail loudly rather
//! than skipping — the end-to-end path is the point.

use morpho::graphics::{Mat3, TransformPipeline, Transform};
use morpho::runtime::Executor;

fn executor() -> Executor {
    Executor::discover().expect("run `make artifacts` first")
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn all_artifacts_compile() {
    let exe = executor();
    let names: Vec<String> = exe.registry().names().map(String::from).collect();
    assert!(names.len() >= 9, "expected the full artifact set, got {names:?}");
    exe.warm_up(names.iter().map(String::as_str)).unwrap();
    assert_eq!(exe.cached(), names.len());
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn translate64_matches_native() {
    let exe = executor();
    let u: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let v: Vec<f32> = (0..64).map(|i| 1000.0 + 3.0 * i as f32).collect();
    let out = exe.run_f32("translate64", &[&u, &v]).unwrap();
    assert_eq!(out.len(), 1);
    let expected: Vec<f32> = u.iter().zip(&v).map(|(a, b)| a + b).collect();
    assert_eq!(out[0], expected);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn scale64_matches_native() {
    let exe = executor();
    let u: Vec<f32> = (0..64).map(|i| i as f32 - 32.0).collect();
    let out = exe.run_f32("scale64", &[&u, &[5.0f32]]).unwrap();
    let expected: Vec<f32> = u.iter().map(|a| 5.0 * a).collect();
    assert_eq!(out[0], expected);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn affine64_matches_native_pipeline() {
    let exe = executor();
    let pipe = TransformPipeline::new(vec![
        Transform::Rotate { theta: 0.37 },
        Transform::Scale { sx: 1.5, sy: 0.75 },
        Transform::Translate { tx: 12.0, ty: -8.0 },
    ]);
    let m = pipe.matrix();
    let [a, b, c, d] = m.linear();
    let (tx, ty) = m.translation();
    let params = [a, b, c, d, tx, ty];

    let xs: Vec<f32> = (0..64).map(|i| (i as f32) * 0.5 - 16.0).collect();
    let ys: Vec<f32> = (0..64).map(|i| (i as f32) * -0.25 + 8.0).collect();
    let out = exe.run_f32("affine64", &[&xs, &ys, &params]).unwrap();
    assert_eq!(out.len(), 2);

    let mut nx = xs.clone();
    let mut ny = ys.clone();
    pipe.apply_native(&mut nx, &mut ny);
    for i in 0..64 {
        assert!((out[0][i] - nx[i]).abs() < 1e-3, "x[{i}]: {} vs {}", out[0][i], nx[i]);
        assert!((out[1][i] - ny[i]).abs() < 1e-3, "y[{i}]: {} vs {}", out[1][i], ny[i]);
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn affine4096_handles_bulk_tiles() {
    let exe = executor();
    let n = 4096;
    let xs: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
    let ys: Vec<f32> = (0..n).map(|i| -(i as f32) * 0.02).collect();
    let params = [2.0f32, 0.0, 0.0, 2.0, 1.0, 1.0];
    let out = exe.run_f32("affine4096", &[&xs, &ys, &params]).unwrap();
    for i in (0..n).step_by(997) {
        assert!((out[0][i] - (2.0 * xs[i] + 1.0)).abs() < 1e-3);
        assert!((out[1][i] - (2.0 * ys[i] + 1.0)).abs() < 1e-3);
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn pipeline3_matches_composed_native() {
    let exe = executor();
    let n = 1024;
    let xs: Vec<f32> = (0..n).map(|i| (i % 101) as f32 - 50.0).collect();
    let ys: Vec<f32> = (0..n).map(|i| (i % 73) as f32 - 36.0).collect();
    let stages = [
        Transform::Scale { sx: 2.0, sy: 2.0 },
        Transform::Rotate { theta: std::f32::consts::FRAC_PI_4 },
        Transform::Translate { tx: -3.0, ty: 9.0 },
    ];
    let ps: Vec<[f32; 6]> = stages
        .iter()
        .map(|t| {
            let m = t.matrix();
            let [a, b, c, d] = m.linear();
            let (tx, ty) = m.translation();
            [a, b, c, d, tx, ty]
        })
        .collect();
    let out = exe
        .run_f32("pipeline3_1024", &[&xs, &ys, &ps[0], &ps[1], &ps[2]])
        .unwrap();

    let pipe = TransformPipeline::new(stages.to_vec());
    let mut nx = xs.clone();
    let mut ny = ys.clone();
    pipe.apply_native(&mut nx, &mut ny);
    for i in (0..n).step_by(131) {
        assert!((out[0][i] - nx[i]).abs() < 1e-2, "x[{i}]: {} vs {}", out[0][i], nx[i]);
        assert!((out[1][i] - ny[i]).abs() < 1e-2);
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn matmul8_matches_native() {
    let exe = executor();
    let a: Vec<f32> = (0..64).map(|i| ((i * 7) % 13) as f32 - 6.0).collect();
    let b: Vec<f32> = (0..64).map(|i| ((i * 5) % 11) as f32 - 5.0).collect();
    let out = exe
        .run_f32_shaped("matmul8", &[(&a, &[8, 8]), (&b, &[8, 8])])
        .unwrap();
    for i in 0..8 {
        for j in 0..8 {
            let expected: f32 = (0..8).map(|k| a[i * 8 + k] * b[k * 8 + j]).sum();
            assert!((out[0][i * 8 + j] - expected).abs() < 1e-3, "C[{i}][{j}]");
        }
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn rotation_via_matmul_artifact_matches_mat3() {
    // Rotation as the paper does it (§5.3): a matrix product. Rotate the
    // 8 corners of a square via matmul8 against Mat3 reference.
    let exe = executor();
    let theta = 0.61f32;
    let (s, c) = theta.sin_cos();
    // Rotation matrix embedded in an 8×8 identity-padded matrix.
    let mut rot = vec![0f32; 64];
    for i in 0..8 {
        rot[i * 8 + i] = 1.0;
    }
    rot[0] = c;
    rot[1] = -s;
    rot[8] = s;
    rot[9] = c;
    // Points as columns: row 0 = xs, row 1 = ys.
    let pts: [(f32, f32); 8] =
        [(1.0, 1.0), (-1.0, 1.0), (-1.0, -1.0), (1.0, -1.0), (2.0, 0.0), (0.0, 2.0), (3.0, -1.0), (-2.0, 2.0)];
    let mut b = vec![0f32; 64];
    for (j, (x, y)) in pts.iter().enumerate() {
        b[j] = *x;
        b[8 + j] = *y;
    }
    let out = exe
        .run_f32_shaped("matmul8", &[(&rot, &[8, 8]), (&b, &[8, 8])])
        .unwrap();
    for (j, (x, y)) in pts.iter().enumerate() {
        let q = Mat3::rotate(theta).apply(morpho::graphics::Point2::new(*x, *y));
        assert!((out[0][j] - q.x).abs() < 1e-4);
        assert!((out[0][8 + j] - q.y).abs() < 1e-4);
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn affine3d_matches_mat4_and_m1_mapping() {
    // Cross-layer agreement: the AOT 3-D artifact (L1/L2), the Mat4
    // native path (L3), and the M1 Point3 mapping (simulator) must agree
    // on an integer-exact transform.
    use morpho::graphics::three_d::Mat4;
    use morpho::mapping::{runner::run_routine3_on, Point3TransformMapping};
    use morpho::morphosys::M1System;

    let exe = executor();
    let n = 1024;
    let xs: Vec<f32> = (0..n).map(|i| (i % 101) as f32 - 50.0).collect();
    let ys: Vec<f32> = (0..n).map(|i| (i % 83) as f32 - 41.0).collect();
    let zs: Vec<f32> = (0..n).map(|i| (i % 67) as f32 - 33.0).collect();
    // Integer transform: swap axes + translate.
    let m = Mat4 {
        m: [
            [0.0, -1.0, 0.0, 5.0],
            [1.0, 0.0, 0.0, -3.0],
            [0.0, 0.0, 1.0, 7.0],
            [0.0, 0.0, 0.0, 1.0],
        ],
    };
    let params = m.affine_params();
    let out = exe.run_f32("affine3d_1024", &[&xs, &ys, &zs, &params]).unwrap();
    assert_eq!(out.len(), 3);
    for i in (0..n).step_by(37) {
        let p = m.apply(morpho::graphics::Point3::new(xs[i], ys[i], zs[i]));
        assert!((out[0][i] - p.x).abs() < 1e-3);
        assert!((out[1][i] - p.y).abs() < 1e-3);
        assert!((out[2][i] - p.z).abs() < 1e-3);
    }

    // M1 mapping on the first 64 points (Q0 integer matrix).
    let mapping = Point3TransformMapping {
        n: 64,
        m: [0, -1, 0, 1, 0, 0, 0, 0, 1],
        t: [5, -3, 7],
        shift: 0,
    };
    let ix: Vec<i16> = xs[..64].iter().map(|v| *v as i16).collect();
    let iy: Vec<i16> = ys[..64].iter().map(|v| *v as i16).collect();
    let iz: Vec<i16> = zs[..64].iter().map(|v| *v as i16).collect();
    let sim = run_routine3_on(&mut M1System::new(), &mapping.compile(), &ix, Some(&iy), Some(&iz));
    let (sx, rest) = sim.result.split_at(64);
    let (sy, sz) = rest.split_at(64);
    for i in 0..64 {
        assert_eq!(sx[i] as f32, out[0][i], "x[{i}]");
        assert_eq!(sy[i] as f32, out[1][i], "y[{i}]");
        assert_eq!(sz[i] as f32, out[2][i], "z[{i}]");
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "needs the real PJRT runtime: vendor the `xla` crate, enable the `pjrt` feature, run `make artifacts`")]
fn corrupt_artifact_fails_loudly_not_silently() {
    use morpho::runtime::{ArtifactRegistry, Executor};
    let tmp = std::env::temp_dir().join(format!("morpho-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::write(tmp.join("bad.hlo.txt"), "HloModule bad\nthis is not hlo").unwrap();
    let exec = Executor::new(ArtifactRegistry::open(&tmp).unwrap()).unwrap();
    let err = exec.run_f32("bad", &[&[1.0f32]]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad"), "error should name the artifact: {msg}");
    // Unknown artifacts are also a clean error.
    assert!(exec.run_f32("nonexistent", &[]).is_err());
    std::fs::remove_dir_all(&tmp).ok();
}
