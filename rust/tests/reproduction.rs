//! The reproduction gate: every table and figure of the paper's
//! evaluation regenerates, the calibrated cells match the paper exactly,
//! and every published comparison's verdict (who wins, by roughly what
//! factor) holds in the measured data.

use morpho::perf::{figure, render_table, table3, table4, table5};

/// The paper's six Table 5 M1 cells.
const PAPER_M1: [(&str, usize, u64); 6] = [
    ("translation", 64, 96),
    ("scaling", 64, 55),
    ("rotation-I", 64, 256),
    ("rotation-II", 16, 70),
    ("translation", 8, 21),
    ("scaling", 8, 14),
];

#[test]
fn table5_m1_vector_cells_match_paper_exactly() {
    let blocks = table5();
    for (alg, n, cycles) in PAPER_M1 {
        if alg.starts_with("rotation") {
            continue; // covered by the shape test below
        }
        let row = blocks
            .iter()
            .flatten()
            .find(|r| r.algorithm == alg && r.n == n && r.system == "M1")
            .unwrap();
        assert_eq!(row.cycles, cycles, "{alg} n={n}");
    }
}

#[test]
fn table5_rotation_cells_within_2x_of_paper() {
    let blocks = table5();
    for (alg, n, cycles) in PAPER_M1.iter().filter(|(a, _, _)| a.starts_with("rotation")) {
        let row = blocks
            .iter()
            .flatten()
            .find(|r| &r.algorithm == alg && r.n == *n && r.system == "M1")
            .unwrap();
        let ratio = row.cycles as f64 / *cycles as f64;
        assert!((0.4..2.0).contains(&ratio), "{alg}: {} vs paper {}", row.cycles, cycles);
    }
}

#[test]
fn every_published_speedup_verdict_holds() {
    // For every non-M1 row of Table 5, the measured speedup must agree
    // with the paper's within a factor of 2.5 (the baselines' published
    // sums contain arithmetic slips; the verdicts never flip).
    use morpho::perf::paper::TABLE5;
    let blocks = table5();
    for block in &blocks {
        let m1 = &block[0];
        for row in &block[1..] {
            let measured_speedup = row.cycles as f64 / m1.cycles as f64;
            let paper_row = TABLE5
                .iter()
                .find(|p| p.algorithm == row.algorithm && p.system == row.system && p.n == row.n)
                .unwrap();
            let paper_speedup = paper_row.speedup.unwrap();
            let ratio = measured_speedup / paper_speedup;
            assert!(
                (0.4..=2.5).contains(&ratio),
                "{} {} n={}: measured speedup {measured_speedup:.2} vs paper {paper_speedup:.2}",
                row.algorithm,
                row.system,
                row.n
            );
            assert!(measured_speedup > 1.0, "M1 must win every published comparison");
        }
    }
}

#[test]
fn tables_3_and_4_regenerate() {
    let t3 = table3();
    assert_eq!(t3.len(), 4);
    // The exactly-reproducible cells (the paper's internally consistent
    // ones): all of Table 4, and Table 3's 8-element rows.
    let t4 = table4();
    for row in &t4 {
        assert_eq!(Some(row.cycles), row.paper_cycles, "Table 4 {} n={}", row.system, row.n);
    }
    for row in t3.iter().filter(|r| r.n == 8) {
        assert_eq!(Some(row.cycles), row.paper_cycles, "Table 3 {} n=8", row.system);
    }
}

#[test]
fn all_eight_figures_regenerate_with_m1_winning() {
    for num in 9..=16 {
        let (_, rows, _) = figure(num);
        let m1 = rows.iter().find(|r| r.system == "M1").unwrap();
        for other in rows.iter().filter(|r| r.system != "M1") {
            assert!(m1.cycles < other.cycles, "figure {num}: M1 must win");
        }
    }
}

#[test]
fn rendered_table5_matches_paper_elements_per_cycle() {
    // Spot-check the derived metrics the paper quotes in §6.1/§6.2:
    // 0.667 el/cycle (64-el translation), 1.16 (64-el scaling),
    // 0.38 (8-el translation), 0.57 (8-el scaling).
    let blocks = table5();
    let get = |alg: &str, n: usize| {
        blocks
            .iter()
            .flatten()
            .find(|r| r.algorithm == alg && r.n == n && r.system == "M1")
            .unwrap()
            .elems_per_cycle()
    };
    assert!((get("translation", 64) - 0.667).abs() < 0.01);
    assert!((get("scaling", 64) - 1.16).abs() < 0.01);
    assert!((get("translation", 8) - 0.38).abs() < 0.01);
    assert!((get("scaling", 8) - 0.57).abs() < 0.01);
    // And the render itself carries the paper column.
    let s = render_table("t5", &blocks);
    assert!(s.contains("96"));
    assert!(s.contains("Δpaper%"));
}
