//! End-to-end coordinator over the real backends, including the XLA
//! (PJRT artifact) path. Requires `make artifacts`.

use std::sync::Arc;
use std::time::Duration;

use morpho::coordinator::{
    BackendChoice, BackendKind, BatcherConfig, Coordinator, CoordinatorConfig,
};
use morpho::graphics::{Transform, TransformPipeline};

fn xla_coordinator(workers: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        backend: BackendChoice::Xla,
        workers,
        batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
        ..Default::default()
    })
    .unwrap()
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "asserts the XLA backend kind; without the (vendored-xla) `pjrt` feature workers fall back to native")]
fn xla_backend_serves_correct_transforms() {
    let c = xla_coordinator(1);
    let n = 500;
    let xs: Vec<f32> = (0..n).map(|i| i as f32 * 0.25 - 60.0).collect();
    let ys: Vec<f32> = (0..n).map(|i| (i % 37) as f32).collect();
    let transforms = vec![
        Transform::Rotate { theta: 0.8 },
        Transform::Translate { tx: 5.0, ty: -2.0 },
    ];
    let resp = c.transform_blocking(xs.clone(), ys.clone(), transforms.clone()).unwrap();
    assert_eq!(resp.timing.backend, BackendKind::Xla);

    let pipe = TransformPipeline::new(transforms);
    let mut nx = xs;
    let mut ny = ys;
    pipe.apply_native(&mut nx, &mut ny);
    for i in 0..n {
        assert!((resp.xs[i] - nx[i]).abs() < 1e-2, "x[{i}]: {} vs {}", resp.xs[i], nx[i]);
        assert!((resp.ys[i] - ny[i]).abs() < 1e-2);
    }
    c.shutdown();
}

#[test]
fn xla_backend_handles_concurrent_clients() {
    let c = Arc::new(xla_coordinator(1));
    let handles: Vec<_> = (0..6)
        .map(|t| {
            let c = c.clone();
            std::thread::spawn(move || {
                for i in 0..10u64 {
                    let n = 64 + (t * 100 + i as usize * 7) % 1000;
                    let xs: Vec<f32> = (0..n).map(|k| k as f32).collect();
                    let ys = vec![1.0f32; n];
                    let tx = (t % 2) as f32 * 3.0;
                    let resp = c
                        .transform_blocking(
                            xs.clone(),
                            ys,
                            vec![Transform::Translate { tx, ty: 0.5 }],
                        )
                        .unwrap();
                    for k in (0..n).step_by(97) {
                        assert!((resp.xs[k] - (xs[k] + tx)).abs() < 1e-3);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = c.metrics();
    assert_eq!(m.requests, 60);
    assert_eq!(m.backend_errors, 0);
}

#[test]
fn all_three_backends_agree() {
    let n = 128;
    // Integer coordinates + integer translation so the M1's 16-bit
    // fixed-point path is exact (fractional inputs quantize by design).
    let xs: Vec<f32> = (0..n).map(|i| i as f32 - 64.0).collect();
    let ys: Vec<f32> = (0..n).map(|i| 32.0 - ((i as f32) * 0.5).floor() * 2.0).collect();
    let transforms = vec![Transform::Translate { tx: 7.0, ty: -3.0 }];

    let mut answers = Vec::new();
    for choice in [BackendChoice::Native, BackendChoice::Xla, BackendChoice::M1Sim] {
        let c = Coordinator::start(CoordinatorConfig {
            backend: choice,
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        let resp = c.transform_blocking(xs.clone(), ys.clone(), transforms.clone()).unwrap();
        answers.push((choice, resp));
        c.shutdown();
    }
    let native = answers[0].1.clone();
    for (choice, resp) in &answers[1..] {
        for i in 0..n {
            assert!(
                (resp.xs[i] - native.xs[i]).abs() < 1e-3,
                "{choice:?} x[{i}]: {} vs {}",
                resp.xs[i],
                native.xs[i]
            );
            assert!((resp.ys[i] - native.ys[i]).abs() < 1e-3);
        }
    }
    // The M1 path must also have reported cycles.
    assert!(answers[2].1.timing.simulated_cycles.unwrap() > 0);
}

#[test]
fn backpressure_bounds_queue_growth() {
    // A tiny queue with the (slower) simulator backend: submissions must
    // block rather than grow unboundedly, and everything still completes.
    let c = Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendChoice::M1Sim,
            queue_capacity: 4,
            job_capacity: 4,
            workers: 1,
            // Sharded tile pool under backpressure: same responses, the
            // worker just fans tiles across two simulators.
            m1_shards: 2,
            batcher: BatcherConfig { max_wait: Duration::from_micros(100), ..Default::default() },
            ..Default::default()
        })
        .unwrap(),
    );
    let receivers: Vec<_> = (0..40)
        .map(|i| {
            c.submit(
                vec![i as f32; 64],
                vec![0.0; 64],
                vec![Transform::Translate { tx: 1.0, ty: 1.0 }],
            )
            .unwrap()
        })
        .collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv().unwrap().expect("no TTL configured, nothing is shed");
        assert_eq!(resp.xs[0], i as f32 + 1.0);
    }
}

#[test]
fn batching_merges_same_transform_requests() {
    // Submit many tiny same-transform requests quickly with a generous
    // batching window: total jobs must be well below request count.
    let c = Coordinator::start(CoordinatorConfig {
        backend: BackendChoice::Native,
        workers: 1,
        batcher: BatcherConfig {
            max_wait: Duration::from_millis(20),
            flush_points: 4096,
            max_tile: 4096,
        },
        ..Default::default()
    })
    .unwrap();
    let receivers: Vec<_> = (0..100)
        .map(|i| {
            c.submit(
                vec![i as f32; 8],
                vec![0.0; 8],
                vec![Transform::Scale { sx: 2.0, sy: 2.0 }],
            )
            .unwrap()
        })
        .collect();
    for rx in receivers {
        rx.recv().unwrap().expect("no TTL configured, nothing is shed");
    }
    let m = c.metrics();
    assert_eq!(m.requests, 100);
    assert!(
        m.jobs <= 50,
        "expected dynamic batching to merge requests: jobs={} requests={}",
        m.jobs,
        m.requests
    );
    assert!(m.mean_batch_points() >= 16.0);
    c.shutdown();
}

#[test]
fn dropped_receiver_does_not_wedge_the_coordinator() {
    // A client that submits and walks away must not poison the worker:
    // subsequent requests still complete.
    let c = Coordinator::start(CoordinatorConfig {
        backend: BackendChoice::Native,
        workers: 1,
        ..Default::default()
    })
    .unwrap();
    for i in 0..20 {
        let rx = c
            .submit(vec![i as f32; 32], vec![0.0; 32], vec![Transform::Scale { sx: 2.0, sy: 2.0 }])
            .unwrap();
        drop(rx); // client gone before the response
    }
    // A patient client still gets served.
    let resp = c
        .transform_blocking(vec![21.0], vec![1.0], vec![Transform::Scale { sx: 2.0, sy: 2.0 }])
        .unwrap();
    assert_eq!(resp.xs, vec![42.0]);
    assert_eq!(c.metrics().requests, 21);
    c.shutdown();
}

#[test]
fn nonfinite_params_are_served_not_crashed() {
    // NaN transforms are the client's prerogative; the service must not
    // panic (native semantics propagate the NaN).
    let c = Coordinator::start(CoordinatorConfig::default()).unwrap();
    let resp = c
        .transform_blocking(
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![Transform::Scale { sx: f32::NAN, sy: 1.0 }],
        )
        .unwrap();
    assert!(resp.xs[0].is_nan());
    assert_eq!(resp.ys[1], 4.0);
    c.shutdown();
}
