//! Randomized differential conformance suite.
//!
//! Three executors must agree **bit-for-bit** on every workload this file
//! can generate:
//!
//! 1. the TinyRISC **interpreter** (`M1System::run`) — the reference;
//! 2. the pre-decoded **scheduled path** (`run_program` with a compiled
//!    `BroadcastSchedule`), including its unchecked validated plane reads;
//! 3. **pooled** execution (`M1SimBackend::with_shards`) against the
//!    serial backend, across shard counts.
//!
//! Agreement is checked on cell planes (all 64 cells' registers, output,
//! accumulator and express latch), the full frame buffer, context memory,
//! the main-memory window programs write to, and cycle accounting.
//!
//! Every case derives from a deterministic seed. CI runs a fixed seed
//! matrix by exporting `CONFORMANCE_SEED`, which perturbs the base seed
//! so each matrix entry explores a disjoint case set; failures print the
//! exact per-case seed to reproduce locally.

use morpho::coordinator::backend::{apply_native, Backend, M1SimBackend};
use morpho::morphosys::context_memory::Block;
use morpho::morphosys::frame_buffer::BANK_ELEMS;
use morpho::morphosys::rc_array::ARRAY_DIM;
use morpho::morphosys::{Bank, BroadcastSchedule, Instruction, M1System, Program, Reg, Set};
use morpho::testkit::Rng;

/// Words of main memory the generator stages into and programs may write;
/// the differential check compares this whole window.
const MEM_WINDOW: usize = 0x2000;

/// Base seed, perturbed by the `CONFORMANCE_SEED` env var (the CI seed
/// matrix).
fn seed_base() -> u64 {
    std::env::var("CONFORMANCE_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .map(|s| 0x5EED_0000_0000_0000 ^ (s.wrapping_mul(0x9E37_79B9_7F4A_7C15)))
        .unwrap_or(0x5EED_C0FF_EE00_0001)
}

/// Run `cases` seeded cases, printing the reproducing seed on failure.
fn for_each_case(name: &str, cases: u64, mut case: impl FnMut(&mut Rng)) {
    let base = seed_base();
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0xA24B_AED4_963E_E407));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| case(&mut rng)));
        if let Err(e) = result {
            eprintln!("conformance `{name}` failed on case {i} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

fn rand_set(rng: &mut Rng) -> Set {
    Set::from_index(rng.below(2) as usize)
}

fn rand_bank(rng: &mut Rng) -> Bank {
    Bank::from_index(rng.below(2) as usize)
}

/// Mostly-low frame-buffer address with a valid 8-element bus window;
/// occasionally the exact top of the bank to exercise the validated-read
/// boundary.
fn rand_bus_addr(rng: &mut Rng) -> usize {
    match rng.below(10) {
        0 => BANK_ELEMS - ARRAY_DIM,
        1..=2 => rng.below((BANK_ELEMS - ARRAY_DIM + 1) as u64) as usize,
        _ => rng.below(256) as usize,
    }
}

/// Emit `ldui`/`ldli` loading `addr` (within the memory window) into `rd`.
fn emit_load_addr(prog: &mut Vec<Instruction>, rd: Reg, addr: usize) {
    prog.push(Instruction::Ldui { rd, imm: (addr >> 16) as u16 });
    prog.push(Instruction::Ldli { rd, imm: (addr & 0xFFFF) as u16 });
}

/// Data staged identically into both systems' main memories before a run.
struct Staging {
    elements: Vec<(usize, Vec<i16>)>,
}

impl Staging {
    fn random(rng: &mut Rng) -> Staging {
        // A few blocks of random elements: vector data for DMA fills plus
        // raw words that become (arbitrary) context words via ldctxt.
        let mut elements = Vec::new();
        for _ in 0..rng.range_i64(2, 5) {
            let addr = rng.below((MEM_WINDOW / 2) as u64) as usize;
            let len = rng.range_i64(8, 128) as usize;
            let data: Vec<i16> = (0..len).map(|_| rng.i16()).collect();
            elements.push((addr, data));
        }
        Staging { elements }
    }

    fn apply(&self, sys: &mut M1System) {
        for (addr, data) in &self.elements {
            sys.mem.store_elements(*addr, data);
        }
    }
}

/// Generate a random straight-line TinyRISC program whose every access is
/// in range (the interpreter panics on out-of-range accesses, so a valid
/// generator is part of the differential contract).
fn random_program(rng: &mut Rng) -> Program {
    let mut prog = Vec::new();
    let ops = rng.range_i64(6, 40);
    for _ in 0..ops {
        let r = Reg(rng.range_i64(1, 7) as u8);
        match rng.below(12) {
            // DMA fill: main memory → frame buffer.
            0..=1 => {
                let words = rng.range_i64(1, 32) as usize;
                let fb_addr = rng.below((BANK_ELEMS - 2 * words + 1) as u64) as usize;
                let mem_addr = rng.below((MEM_WINDOW - words) as u64) as usize;
                emit_load_addr(&mut prog, r, mem_addr);
                prog.push(Instruction::Ldfb {
                    rs: r,
                    set: rand_set(rng),
                    bank: rand_bank(rng),
                    words,
                    fb_addr,
                });
            }
            // DMA drain: frame buffer → main memory.
            2 => {
                let words = rng.range_i64(1, 32) as usize;
                let fb_addr = rng.below((BANK_ELEMS - 2 * words + 1) as u64) as usize;
                let mem_addr = rng.below((MEM_WINDOW - words) as u64) as usize;
                emit_load_addr(&mut prog, r, mem_addr);
                prog.push(Instruction::Stfb {
                    rs: r,
                    set: rand_set(rng),
                    bank: rand_bank(rng),
                    words,
                    fb_addr,
                });
            }
            // Context load: arbitrary staged words decode to arbitrary
            // context words — the broadcast semantics space.
            3..=4 => {
                let count = rng.range_i64(1, 8) as usize;
                let word = rng.below((16 - count + 1) as u64) as usize;
                let mem_addr = rng.below((MEM_WINDOW - count) as u64) as usize;
                emit_load_addr(&mut prog, r, mem_addr);
                prog.push(Instruction::Ldctxt {
                    rs: r,
                    block: if rng.bool() { Block::Column } else { Block::Row },
                    plane: rng.below(2) as usize,
                    word,
                    count,
                });
            }
            // Broadcasts: the hot differential surface (validated
            // unchecked plane reads vs the interpreter's checked reads).
            5..=8 => {
                let plane = rng.below(2) as usize;
                let cw = rng.below(16) as usize;
                let line = rng.below(8) as usize;
                let set = rand_set(rng);
                match rng.below(4) {
                    0 => prog.push(Instruction::Dbcdc {
                        plane,
                        cw,
                        col: line,
                        set,
                        addr_a: rand_bus_addr(rng),
                        addr_b: rand_bus_addr(rng),
                    }),
                    1 => prog.push(Instruction::Dbcdr {
                        plane,
                        cw,
                        row: line,
                        set,
                        addr_a: rand_bus_addr(rng),
                        addr_b: rand_bus_addr(rng),
                    }),
                    2 => prog.push(Instruction::Sbcb {
                        plane,
                        cw,
                        col: line,
                        set,
                        bank: rand_bank(rng),
                        addr: rand_bus_addr(rng),
                    }),
                    _ => prog.push(Instruction::Sbcbr {
                        plane,
                        cw,
                        row: line,
                        set,
                        bank: rand_bank(rng),
                        addr: rand_bus_addr(rng),
                    }),
                }
            }
            // Write-backs of line outputs.
            9 => {
                let line = rng.below(8) as usize;
                let set = rand_set(rng);
                let bank = rand_bank(rng);
                let addr = rng.below((BANK_ELEMS - ARRAY_DIM + 1) as u64) as usize;
                if rng.bool() {
                    prog.push(Instruction::Wfbi { col: line, set, bank, addr });
                } else {
                    prog.push(Instruction::Wfbir { row: line, set, bank, addr });
                }
            }
            // Scalar ops.
            10 => {
                let rs = Reg(rng.below(8) as u8);
                let rt = Reg(rng.below(8) as u8);
                match rng.below(3) {
                    0 => prog.push(Instruction::Add { rd: r, rs, rt }),
                    1 => prog.push(Instruction::Sub { rd: r, rs, rt }),
                    _ => prog.push(Instruction::Addi {
                        rd: r,
                        rs,
                        imm: rng.range_i64(-100, 100) as i16,
                    }),
                }
            }
            // Rare early halt (anything after is dead in both executors).
            _ => {
                if rng.below(8) == 0 {
                    prog.push(Instruction::Halt);
                    break;
                }
                prog.push(Instruction::NOP);
            }
        }
    }
    Program::new(prog)
}

/// Assert two systems are architecturally identical after a run.
fn assert_systems_identical(a: &M1System, b: &M1System, what: &str) {
    for row in 0..ARRAY_DIM {
        for col in 0..ARRAY_DIM {
            assert_eq!(a.array.cell(row, col), b.array.cell(row, col), "{what}: cell ({row},{col})");
        }
    }
    for set in [Set::Zero, Set::One] {
        for bank in [Bank::A, Bank::B] {
            assert_eq!(
                a.fb.read_slice(set, bank, 0, BANK_ELEMS),
                b.fb.read_slice(set, bank, 0, BANK_ELEMS),
                "{what}: FB {set:?}/{bank:?}"
            );
        }
    }
    for block in [Block::Column, Block::Row] {
        for plane in 0..2 {
            for word in 0..16 {
                assert_eq!(
                    a.ctx.read(block, plane, word),
                    b.ctx.read(block, plane, word),
                    "{what}: ctx {block:?}/{plane}/{word}"
                );
            }
        }
    }
    assert_eq!(
        a.mem.load_elements(0, 2 * MEM_WINDOW),
        b.mem.load_elements(0, 2 * MEM_WINDOW),
        "{what}: main-memory window"
    );
}

#[test]
fn random_programs_scheduled_path_is_bit_identical_to_interpreter() {
    for_each_case("scheduled == interpreter", 220, |rng| {
        let staging = Staging::random(rng);
        let program = random_program(rng);
        let schedule = BroadcastSchedule::compile(&program)
            .expect("straight-line programs always compile");

        let mut interp = M1System::new();
        staging.apply(&mut interp);
        let ri = interp.run(&program);

        let mut sched = M1System::new();
        staging.apply(&mut sched);
        let rs = sched.run_program(&program, Some(&schedule));

        assert_eq!(ri.cycles, rs.cycles, "cycles");
        assert_eq!(ri.slots, rs.slots, "slots");
        assert_eq!(ri.executed, rs.executed, "executed");
        assert_eq!(ri.broadcasts, rs.broadcasts, "broadcasts");
        assert_systems_identical(&interp, &sched, "post-run state");
    });
}

#[test]
fn most_generated_schedules_take_the_validated_fast_path() {
    // The generator only emits in-range addresses, so every schedule must
    // validate — i.e. the unchecked-read path is what the differential
    // test above actually exercises.
    for_each_case("schedules validate", 50, |rng| {
        let program = random_program(rng);
        assert!(BroadcastSchedule::compile(&program).unwrap().is_validated());
    });
}

/// Deterministic, exactly-quantizable affine params: matrix entries are
/// multiples of 2⁻⁶ within the Q6 i8 range, translations small integers.
fn random_quantizable_params(rng: &mut Rng) -> [f32; 6] {
    let q = |rng: &mut Rng| rng.range_i64(-127, 127) as f32 / 64.0;
    [
        q(rng),
        q(rng),
        q(rng),
        q(rng),
        rng.range_i64(-100, 100) as f32,
        rng.range_i64(-100, 100) as f32,
    ]
}

fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn pooled_backend_matches_serial_across_shard_counts_and_sizes() {
    // The acceptance grid: shard counts {1, 2, 4, 8} × n ∈ {64, 500,
    // 2117, 4096}, byte-identical outputs and identical aggregate cycles.
    let params = [0.5, -0.25, 0.25, 0.5, 7.0, -3.0];
    for &n in &[64usize, 500, 2117, 4096] {
        let mut rng = Rng::new(0xBA5E ^ n as u64);
        let base_x: Vec<f32> = (0..n).map(|_| rng.range_i64(-2000, 2000) as f32).collect();
        let base_y: Vec<f32> = (0..n).map(|_| rng.range_i64(-2000, 2000) as f32).collect();

        let mut serial = M1SimBackend::new();
        let (mut sx, mut sy) = (base_x.clone(), base_y.clone());
        let sc = serial.apply(&params, &mut sx, &mut sy).unwrap().unwrap();

        for shards in [1usize, 2, 4, 8] {
            let mut pooled = M1SimBackend::with_shards(shards);
            let (mut px, mut py) = (base_x.clone(), base_y.clone());
            let pc = pooled.apply(&params, &mut px, &mut py).unwrap().unwrap();
            assert_bits_equal(&sx, &px, &format!("xs n={n} shards={shards}"));
            assert_bits_equal(&sy, &py, &format!("ys n={n} shards={shards}"));
            assert_eq!(
                sc.to_bits(),
                pc.to_bits(),
                "aggregate cycles n={n} shards={shards}: {sc} vs {pc}"
            );
        }
    }
}

#[test]
fn pooled_backend_randomized_conformance_against_serial() {
    // Random quantizable transforms over random coordinate sets: serial
    // and pooled execution agree bit-for-bit, including the padded tail
    // tile of non-multiple-of-64 sizes.
    let mut serial = M1SimBackend::new();
    let mut pooled = M1SimBackend::with_shards(4);
    for_each_case("pooled == serial", 200, |rng| {
        let n = rng.range_i64(1, 300) as usize;
        let params = random_quantizable_params(rng);
        let base_x: Vec<f32> = (0..n).map(|_| rng.range_i64(-4000, 4000) as f32).collect();
        let base_y: Vec<f32> = (0..n).map(|_| rng.range_i64(-4000, 4000) as f32).collect();
        let (mut sx, mut sy) = (base_x.clone(), base_y.clone());
        let sc = serial.apply(&params, &mut sx, &mut sy).unwrap();
        let (mut px, mut py) = (base_x, base_y);
        let pc = pooled.apply(&params, &mut px, &mut py).unwrap();
        assert_bits_equal(&sx, &px, "xs");
        assert_bits_equal(&sy, &py, "ys");
        match (sc, pc) {
            (Some(s), Some(p)) => assert_eq!(s.to_bits(), p.to_bits(), "cycles"),
            (s, p) => assert_eq!(s.is_none(), p.is_none(), "fallback disagreement"),
        }
    });
}

#[test]
fn unquantizable_fallback_is_identical_across_shard_counts() {
    // Scale 100× exceeds the Q6 i8 range, and coordinates past the
    // headroom limit force the native path too; both fallbacks must
    // behave identically for every shard count (native result, no
    // simulated cycles).
    for (params, xs) in [
        ([100.0f32, 0.0, 0.0, 100.0, 0.0, 0.0], vec![1.0f32, 2.0, 3.0]),
        ([1.0, 0.0, 0.0, 1.0, 1.0, 1.0], vec![9000.0f32, 1.0]),
    ] {
        let ys = vec![1.0f32; xs.len()];
        let mut want_x = xs.clone();
        let mut want_y = ys.clone();
        apply_native(&params, &mut want_x, &mut want_y);
        for shards in [1usize, 2, 4, 8] {
            let mut backend = M1SimBackend::with_shards(shards);
            let (mut px, mut py) = (xs.clone(), ys.clone());
            let cycles = backend.apply(&params, &mut px, &mut py).unwrap();
            assert_eq!(cycles, None, "shards={shards}");
            assert_bits_equal(&want_x, &px, "fallback xs");
            assert_bits_equal(&want_y, &py, "fallback ys");
        }
    }
}
