//! Randomized differential conformance suite.
//!
//! Three executors must agree **bit-for-bit** on every workload this file
//! can generate:
//!
//! 1. the TinyRISC **interpreter** (`M1System::run`) — the reference;
//! 2. the pre-decoded **scheduled path** (`run_program` with a compiled
//!    `BroadcastSchedule`), including its unchecked validated plane reads;
//! 3. **pooled** execution (`M1SimBackend::with_shards`) against the
//!    serial backend, across shard counts;
//! 4. the **megakernel** tier (`M1System::run_megakernel` with a
//!    plan-level `Megakernel`) against the interpreter, the
//!    scheduled/fused tier, and the per-tile pool decomposition.
//!
//! Agreement is checked on cell planes (all 64 cells' registers, output,
//! accumulator and express latch), the full frame buffer, context memory,
//! the main-memory window programs write to, and cycle accounting.
//!
//! Every case derives from a deterministic seed. CI runs a fixed seed
//! matrix by exporting `CONFORMANCE_SEED`, which perturbs the base seed
//! so each matrix entry explores a disjoint case set; failures print the
//! exact per-case seed to reproduce locally. When `MORPHO_REPRO_DIR` is
//! set, interpreter-vs-scheduled divergences additionally dump a
//! self-contained `.m1ra` artifact (see `morpho::replay`) that
//! `repro replay` reports as divergent.

use morpho::coordinator::backend::{apply_native, Backend, M1SimBackend};
use morpho::morphosys::context_memory::Block;
use morpho::morphosys::frame_buffer::BANK_ELEMS;
use morpho::morphosys::rc_array::ARRAY_DIM;
use morpho::morphosys::{
    AluOp, Bank, BroadcastSchedule, ContextWord, Instruction, M1System, Program, Reg, Set,
};
use morpho::replay::{dump_dir, ReplayOutcome, ReproArtifact};
use morpho::testkit::Rng;
use std::path::{Path, PathBuf};

/// Words of main memory the generator stages into and programs may write;
/// the differential check compares this whole window.
const MEM_WINDOW: usize = 0x2000;

/// Base seed, perturbed by the `CONFORMANCE_SEED` env var (the CI seed
/// matrix).
fn seed_base() -> u64 {
    std::env::var("CONFORMANCE_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .map(|s| 0x5EED_0000_0000_0000 ^ (s.wrapping_mul(0x9E37_79B9_7F4A_7C15)))
        .unwrap_or(0x5EED_C0FF_EE00_0001)
}

/// Run `cases` seeded cases, printing the reproducing seed on failure.
/// The closure also receives the case seed so failure paths can stamp it
/// into dumped repro artifacts.
fn for_each_case(name: &str, cases: u64, mut case: impl FnMut(&mut Rng, u64)) {
    let base = seed_base();
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0xA24B_AED4_963E_E407));
        let mut rng = Rng::new(seed);
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| case(&mut rng, seed)));
        if let Err(e) = result {
            eprintln!("conformance `{name}` failed on case {i} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Build and write a `.m1ra` divergence artifact: the staged pre-state
/// and program with the reference interpreter's per-step digests, plus
/// the *candidate* tier's memory window recorded as the expected result.
/// `repro replay` then re-derives the reference run and reports the
/// divergence (a result mismatch at the first differing element) instead
/// of a clean match.
fn dump_divergence_artifact(
    dir: &Path,
    seed: u64,
    what: &str,
    pre_state: Vec<u8>,
    program: &Program,
    candidate_mem: Vec<i16>,
) -> morpho::Result<PathBuf> {
    let artifact = ReproArtifact::capture(
        seed,
        format!("conformance divergence: {what}"),
        program.clone(),
        pre_state,
        0,
        candidate_mem,
    )?;
    artifact.write_into(dir)
}

/// Run a differential case's assertions; when they fail and
/// `MORPHO_REPRO_DIR` is set, dump a divergence artifact before
/// propagating the panic (ordinary runs never write anything). The
/// `pre_state` and `candidate_mem` closures are only invoked on failure.
fn guard_differential(
    seed: u64,
    what: &str,
    pre_state: impl FnOnce() -> Vec<u8>,
    program: &Program,
    candidate_mem: impl FnOnce() -> Vec<i16>,
    assertions: impl FnOnce(),
) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(assertions));
    if let Err(e) = result {
        if let Some(dir) = dump_dir() {
            match dump_divergence_artifact(&dir, seed, what, pre_state(), program, candidate_mem())
            {
                Ok(path) => {
                    eprintln!("conformance: divergence artifact at {}", path.display());
                }
                Err(err) => eprintln!("conformance: artifact dump failed: {err}"),
            }
        }
        std::panic::resume_unwind(e);
    }
}

fn rand_set(rng: &mut Rng) -> Set {
    Set::from_index(rng.below(2) as usize)
}

fn rand_bank(rng: &mut Rng) -> Bank {
    Bank::from_index(rng.below(2) as usize)
}

/// Mostly-low frame-buffer address with a valid 8-element bus window;
/// occasionally the exact top of the bank to exercise the validated-read
/// boundary.
fn rand_bus_addr(rng: &mut Rng) -> usize {
    match rng.below(10) {
        0 => BANK_ELEMS - ARRAY_DIM,
        1..=2 => rng.below((BANK_ELEMS - ARRAY_DIM + 1) as u64) as usize,
        _ => rng.below(256) as usize,
    }
}

/// Emit `ldui`/`ldli` loading `addr` (within the memory window) into `rd`.
fn emit_load_addr(prog: &mut Vec<Instruction>, rd: Reg, addr: usize) {
    prog.push(Instruction::Ldui { rd, imm: (addr >> 16) as u16 });
    prog.push(Instruction::Ldli { rd, imm: (addr & 0xFFFF) as u16 });
}

/// Data staged identically into both systems' main memories before a run.
struct Staging {
    elements: Vec<(usize, Vec<i16>)>,
}

impl Staging {
    fn random(rng: &mut Rng) -> Staging {
        // A few blocks of random elements: vector data for DMA fills plus
        // raw words that become (arbitrary) context words via ldctxt.
        let mut elements = Vec::new();
        for _ in 0..rng.range_i64(2, 5) {
            let addr = rng.below((MEM_WINDOW / 2) as u64) as usize;
            let len = rng.range_i64(8, 128) as usize;
            let data: Vec<i16> = (0..len).map(|_| rng.i16()).collect();
            elements.push((addr, data));
        }
        Staging { elements }
    }

    fn apply(&self, sys: &mut M1System) {
        for (addr, data) in &self.elements {
            sys.mem.store_elements(*addr, data);
        }
    }
}

/// Generate a random straight-line TinyRISC program whose every access is
/// in range (the interpreter panics on out-of-range accesses, so a valid
/// generator is part of the differential contract).
fn random_program(rng: &mut Rng) -> Program {
    let mut prog = Vec::new();
    let ops = rng.range_i64(6, 40);
    for _ in 0..ops {
        let r = Reg(rng.range_i64(1, 7) as u8);
        match rng.below(12) {
            // DMA fill: main memory → frame buffer.
            0..=1 => {
                let words = rng.range_i64(1, 32) as usize;
                let fb_addr = rng.below((BANK_ELEMS - 2 * words + 1) as u64) as usize;
                let mem_addr = rng.below((MEM_WINDOW - words) as u64) as usize;
                emit_load_addr(&mut prog, r, mem_addr);
                prog.push(Instruction::Ldfb {
                    rs: r,
                    set: rand_set(rng),
                    bank: rand_bank(rng),
                    words,
                    fb_addr,
                });
            }
            // DMA drain: frame buffer → main memory.
            2 => {
                let words = rng.range_i64(1, 32) as usize;
                let fb_addr = rng.below((BANK_ELEMS - 2 * words + 1) as u64) as usize;
                let mem_addr = rng.below((MEM_WINDOW - words) as u64) as usize;
                emit_load_addr(&mut prog, r, mem_addr);
                prog.push(Instruction::Stfb {
                    rs: r,
                    set: rand_set(rng),
                    bank: rand_bank(rng),
                    words,
                    fb_addr,
                });
            }
            // Context load: arbitrary staged words decode to arbitrary
            // context words — the broadcast semantics space.
            3..=4 => {
                let count = rng.range_i64(1, 8) as usize;
                let word = rng.below((16 - count + 1) as u64) as usize;
                let mem_addr = rng.below((MEM_WINDOW - count) as u64) as usize;
                emit_load_addr(&mut prog, r, mem_addr);
                prog.push(Instruction::Ldctxt {
                    rs: r,
                    block: if rng.bool() { Block::Column } else { Block::Row },
                    plane: rng.below(2) as usize,
                    word,
                    count,
                });
            }
            // Broadcasts: the hot differential surface (validated
            // unchecked plane reads vs the interpreter's checked reads).
            5..=8 => {
                let plane = rng.below(2) as usize;
                let cw = rng.below(16) as usize;
                let line = rng.below(8) as usize;
                let set = rand_set(rng);
                match rng.below(4) {
                    0 => prog.push(Instruction::Dbcdc {
                        plane,
                        cw,
                        col: line,
                        set,
                        addr_a: rand_bus_addr(rng),
                        addr_b: rand_bus_addr(rng),
                    }),
                    1 => prog.push(Instruction::Dbcdr {
                        plane,
                        cw,
                        row: line,
                        set,
                        addr_a: rand_bus_addr(rng),
                        addr_b: rand_bus_addr(rng),
                    }),
                    2 => prog.push(Instruction::Sbcb {
                        plane,
                        cw,
                        col: line,
                        set,
                        bank: rand_bank(rng),
                        addr: rand_bus_addr(rng),
                    }),
                    _ => prog.push(Instruction::Sbcbr {
                        plane,
                        cw,
                        row: line,
                        set,
                        bank: rand_bank(rng),
                        addr: rand_bus_addr(rng),
                    }),
                }
            }
            // Write-backs of line outputs.
            9 => {
                let line = rng.below(8) as usize;
                let set = rand_set(rng);
                let bank = rand_bank(rng);
                let addr = rng.below((BANK_ELEMS - ARRAY_DIM + 1) as u64) as usize;
                if rng.bool() {
                    prog.push(Instruction::Wfbi { col: line, set, bank, addr });
                } else {
                    prog.push(Instruction::Wfbir { row: line, set, bank, addr });
                }
            }
            // Scalar ops.
            10 => {
                let rs = Reg(rng.below(8) as u8);
                let rt = Reg(rng.below(8) as u8);
                match rng.below(3) {
                    0 => prog.push(Instruction::Add { rd: r, rs, rt }),
                    1 => prog.push(Instruction::Sub { rd: r, rs, rt }),
                    _ => prog.push(Instruction::Addi {
                        rd: r,
                        rs,
                        imm: rng.range_i64(-100, 100) as i16,
                    }),
                }
            }
            // Rare early halt (anything after is dead in both executors).
            _ => {
                if rng.below(8) == 0 {
                    prog.push(Instruction::Halt);
                    break;
                }
                prog.push(Instruction::NOP);
            }
        }
    }
    Program::new(prog)
}

/// Assert two systems are architecturally identical after a run.
fn assert_systems_identical(a: &M1System, b: &M1System, what: &str) {
    for row in 0..ARRAY_DIM {
        for col in 0..ARRAY_DIM {
            assert_eq!(a.array.cell(row, col), b.array.cell(row, col), "{what}: cell ({row},{col})");
        }
    }
    for set in [Set::Zero, Set::One] {
        for bank in [Bank::A, Bank::B] {
            assert_eq!(
                a.fb.read_slice(set, bank, 0, BANK_ELEMS),
                b.fb.read_slice(set, bank, 0, BANK_ELEMS),
                "{what}: FB {set:?}/{bank:?}"
            );
        }
    }
    for block in [Block::Column, Block::Row] {
        for plane in 0..2 {
            for word in 0..16 {
                assert_eq!(
                    a.ctx.read(block, plane, word),
                    b.ctx.read(block, plane, word),
                    "{what}: ctx {block:?}/{plane}/{word}"
                );
            }
        }
    }
    assert_eq!(
        a.mem.load_elements(0, 2 * MEM_WINDOW),
        b.mem.load_elements(0, 2 * MEM_WINDOW),
        "{what}: main-memory window"
    );
}

#[test]
fn random_programs_scheduled_path_is_bit_identical_to_interpreter() {
    for_each_case("scheduled == interpreter", 220, |rng, seed| {
        let staging = Staging::random(rng);
        let program = random_program(rng);
        let schedule = BroadcastSchedule::compile(&program)
            .expect("straight-line programs always compile");

        let mut interp = M1System::new();
        staging.apply(&mut interp);
        let ri = interp.run(&program);

        let mut sched = M1System::new();
        staging.apply(&mut sched);
        let rs = sched.run_program(&program, Some(&schedule));

        guard_differential(
            seed,
            "scheduled vs interpreter",
            || {
                let mut fresh = M1System::new();
                staging.apply(&mut fresh);
                fresh.snapshot()
            },
            &program,
            || sched.mem.load_elements(0, 2 * MEM_WINDOW),
            || {
                assert_eq!(ri.cycles, rs.cycles, "cycles");
                assert_eq!(ri.slots, rs.slots, "slots");
                assert_eq!(ri.executed, rs.executed, "executed");
                assert_eq!(ri.broadcasts, rs.broadcasts, "broadcasts");
                assert_systems_identical(&interp, &sched, "post-run state");
            },
        );
    });
}

#[test]
fn random_programs_scheduled_path_is_bit_identical_in_both_dma_modes() {
    // The async-DMA differential axis (§Perf PR 5): the same randomized
    // straight-line programs — interleaved DMA fills/drains, context
    // loads, broadcasts, write-backs — run interpreter-vs-scheduled on
    // **async-DMA** systems as well as blocking ones. The schedule's
    // precomputed async issue/readiness accounting and the executed
    // architectural state (cell planes, frame buffer, context memory,
    // memory window) must both be bit-identical to the interpreter's.
    for_each_case("scheduled == interpreter across DMA modes", 220, |rng, seed| {
        let staging = Staging::random(rng);
        let program = random_program(rng);
        let schedule = BroadcastSchedule::compile(&program)
            .expect("straight-line programs always compile");
        for async_dma in [false, true] {
            let mut interp = M1System::with_dma_mode(async_dma);
            staging.apply(&mut interp);
            let ri = interp.run(&program);

            let mut sched = M1System::with_dma_mode(async_dma);
            staging.apply(&mut sched);
            let rs = sched.run_program(&program, Some(&schedule));

            guard_differential(
                seed,
                &format!("scheduled vs interpreter (async={async_dma})"),
                || {
                    let mut fresh = M1System::with_dma_mode(async_dma);
                    staging.apply(&mut fresh);
                    fresh.snapshot()
                },
                &program,
                || sched.mem.load_elements(0, 2 * MEM_WINDOW),
                || {
                    assert_eq!(ri.cycles, rs.cycles, "cycles (async={async_dma})");
                    assert_eq!(ri.slots, rs.slots, "slots (async={async_dma})");
                    assert_eq!(ri.executed, rs.executed, "executed (async={async_dma})");
                    assert_eq!(ri.broadcasts, rs.broadcasts, "broadcasts (async={async_dma})");
                    assert_systems_identical(
                        &interp,
                        &sched,
                        &format!("post-run state (async={async_dma})"),
                    );
                },
            );
        }
    });
}

#[test]
fn forced_divergence_dumps_an_artifact_that_replays_as_divergent() {
    // The artifact contract end to end: force a divergence through the
    // same dump path the differential tests use — a candidate memory
    // window that differs from the reference in one element — and assert
    // the written `.m1ra` file replays as divergent, not as a clean
    // match. Uses an explicit directory rather than `MORPHO_REPRO_DIR`
    // (mutating the env would race parallel tests).
    let seed = 0xD1FF_0000_0000_0001u64;
    let mut rng = Rng::new(seed);
    let staging = Staging::random(&mut rng);
    let program = random_program(&mut rng);

    let mut reference = M1System::new();
    staging.apply(&mut reference);
    let pre_state = reference.snapshot();
    reference.run(&program);

    // The "candidate" result: the reference window with one corrupted
    // element — the smallest divergence a broken tier could produce.
    let mut candidate_mem = reference.mem.load_elements(0, 2 * MEM_WINDOW);
    candidate_mem[123] = candidate_mem[123].wrapping_add(1);

    let dir = std::env::temp_dir().join("morpho-conformance-divergence-test");
    let path =
        dump_divergence_artifact(&dir, seed, "forced unit divergence", pre_state, &program, candidate_mem)
            .expect("artifact dump");

    let artifact = ReproArtifact::read_from(&path).expect("artifact reads back");
    assert_eq!(artifact.seed, seed);
    assert!(artifact.summary.contains("forced unit divergence"));
    let outcome = artifact.replay().expect("artifact replays");
    assert!(!outcome.is_match(), "forced divergence replayed clean: {}", outcome.render());
    match outcome {
        ReplayOutcome::ResultMismatch { index, expected, found } => {
            assert_eq!(index, 123, "divergence must point at the corrupted element");
            assert_eq!(expected, found.wrapping_add(1));
        }
        other => panic!("expected a result mismatch, got {}", other.render()),
    }
    let _ = std::fs::remove_file(path);
}

/// Build the canonical fusable tile program: stage `u`/`v` at 0x100/0x200
/// and a raw context word at 0x300, DMA both banks, load the word, fire
/// `sweeps` full 8-column contiguous double-bank broadcast runs, write all
/// 8 columns back contiguously, and store the result window to 0x400.
/// Every broadcast/write-back run in it is fusion-eligible by
/// construction.
fn fusable_tile_program(sweeps: usize) -> Program {
    let mut prog = Vec::new();
    emit_load_addr(&mut prog, Reg(1), 0x100);
    prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 });
    emit_load_addr(&mut prog, Reg(2), 0x200);
    prog.push(Instruction::Ldfb { rs: Reg(2), set: Set::Zero, bank: Bank::B, words: 32, fb_addr: 0 });
    emit_load_addr(&mut prog, Reg(3), 0x300);
    prog.push(Instruction::Ldctxt { rs: Reg(3), block: Block::Column, plane: 0, word: 0, count: 1 });
    for _ in 0..sweeps {
        for c in 0..ARRAY_DIM {
            // The paper's interleaved bank-address formation step — the
            // fusion pass must hoist these, not refuse the run.
            prog.push(Instruction::Ldli { rd: Reg(4), imm: (c * ARRAY_DIM) as u16 });
            prog.push(Instruction::Dbcdc {
                plane: 0,
                cw: 0,
                col: c,
                set: Set::Zero,
                addr_a: c * ARRAY_DIM,
                addr_b: c * ARRAY_DIM,
            });
        }
    }
    for c in 0..ARRAY_DIM {
        prog.push(Instruction::Wfbi {
            col: c,
            set: Set::One,
            bank: Bank::A,
            addr: c * ARRAY_DIM,
        });
    }
    emit_load_addr(&mut prog, Reg(5), 0x400);
    prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::A, words: 32, fb_addr: 0 });
    Program::new(prog)
}

/// Run one program on three fresh, identically staged systems — the
/// interpreter, the unfused scheduled path, and the fused path — and
/// assert all three agree bit-for-bit on reports and architectural state.
fn assert_three_way_identical(program: &Program, stage: impl Fn(&mut M1System), what: &str) {
    let fused = BroadcastSchedule::compile(program).expect("straight-line program");
    let unfused = BroadcastSchedule::compile_unfused(program).expect("straight-line program");
    let mut interp = M1System::new();
    stage(&mut interp);
    let ri = interp.run(program);
    for (name, schedule) in [("fused", &fused), ("unfused", &unfused)] {
        let mut sys = M1System::new();
        stage(&mut sys);
        let rs = sys.run_program(program, Some(schedule));
        assert_eq!(ri.cycles, rs.cycles, "{what}: {name} cycles");
        assert_eq!(ri.slots, rs.slots, "{what}: {name} slots");
        assert_eq!(ri.executed, rs.executed, "{what}: {name} executed");
        assert_eq!(ri.broadcasts, rs.broadcasts, "{what}: {name} broadcasts");
        assert_systems_identical(&interp, &sys, &format!("{what}: {name} state"));
    }
}

#[test]
fn fused_runs_match_interpreter_for_every_alu_op() {
    // The per-AluOp fused sweep: all 16 ops through the SIMD lane
    // kernels, random operands and context-word flags, two consecutive
    // full-array broadcast runs so `Mula` (and `acc_accumulate`)
    // accumulator state carries from one fused run into the next.
    for op_bits in 0..16u8 {
        let op = AluOp::from_bits(op_bits);
        for_each_case(&format!("fused {op:?}"), 12, |rng, _seed| {
            let mut cw = if op.uses_immediate() {
                ContextWord::immediate(op, rng.range_i64(-128, 127) as i16)
            } else {
                ContextWord::two_port(op)
            };
            cw.reg_write = rng.below(16) as u8;
            cw.express_write = rng.bool();
            // acc_reset=false half the time keeps accumulator state live
            // across the two fused sweeps.
            cw.acc_reset = rng.bool();
            cw.acc_accumulate = rng.below(4) == 0;
            let program = fusable_tile_program(2);
            let schedule = BroadcastSchedule::compile(&program).unwrap();
            assert!(
                schedule.fused_runs() >= 3,
                "{op:?}: expected 2 fused broadcast runs + 1 fused write-back run, got {}",
                schedule.fused_runs()
            );
            let u: Vec<i16> = (0..64).map(|_| rng.i16()).collect();
            let v: Vec<i16> = (0..64).map(|_| rng.i16()).collect();
            let raw = cw.encode();
            assert_three_way_identical(
                &program,
                |sys| {
                    sys.mem.store_elements(0x100, &u);
                    sys.mem.store_elements(0x200, &v);
                    sys.mem.write_word(0x300, raw);
                },
                &format!("{op:?} (cw {raw:#010x})"),
            );
        });
    }
}

#[test]
fn mula_accumulator_carries_across_consecutive_fused_runs() {
    // Directed (non-random) pin of the carry: two fused Mula sweeps
    // without acc_reset — the second run's outputs are acc after TWO
    // accumulations, i.e. 2·u[i]·v[i] in every cell.
    let program = fusable_tile_program(2);
    let u: Vec<i16> = (0..64).map(|i| (i as i16) - 31).collect();
    let v: Vec<i16> = (0..64).map(|i| 3 * (i as i16) - 90).collect();
    let cw = ContextWord::two_port(AluOp::Mula);
    let raw = cw.encode();
    assert_three_way_identical(
        &program,
        |sys| {
            sys.mem.store_elements(0x100, &u);
            sys.mem.store_elements(0x200, &v);
            sys.mem.write_word(0x300, raw);
        },
        "Mula carry",
    );
    // And the numeric expectation, against the fused path directly.
    let schedule = BroadcastSchedule::compile(&program).unwrap();
    let mut sys = M1System::new();
    sys.mem.store_elements(0x100, &u);
    sys.mem.store_elements(0x200, &v);
    sys.mem.write_word(0x300, raw);
    sys.run_program(&program, Some(&schedule));
    let result = sys.mem.load_elements(0x400, 64);
    for i in 0..64 {
        let expect = (2i32 * u[i] as i32 * v[i] as i32) as i16;
        assert_eq!(result[i], expect, "element {i}");
    }
}

#[test]
fn non_contiguous_programs_refuse_fusion_and_stay_bit_identical() {
    // Broadcast runs with a 16-element address stride, alternating
    // context words, or descending lines must refuse fusion entirely —
    // and still execute bit-identically to the interpreter through the
    // unfused scheduled path.
    let variants: Vec<(&str, Vec<Instruction>)> = vec![
        (
            "stride-16 addresses",
            (0..4)
                .map(|c| Instruction::Dbcdc {
                    plane: 0,
                    cw: 0,
                    col: c,
                    set: Set::Zero,
                    addr_a: 16 * c,
                    addr_b: 16 * c,
                })
                .collect(),
        ),
        (
            "alternating context words",
            (0..4)
                .map(|c| Instruction::Dbcdc {
                    plane: 0,
                    cw: c % 2,
                    col: c,
                    set: Set::Zero,
                    addr_a: 8 * c,
                    addr_b: 8 * c,
                })
                .collect(),
        ),
        (
            "descending lines",
            (0..4)
                .map(|c| Instruction::Dbcdc {
                    plane: 0,
                    cw: 0,
                    col: 3 - c,
                    set: Set::Zero,
                    addr_a: 8 * (3 - c),
                    addr_b: 8 * (3 - c),
                })
                .collect(),
        ),
        (
            "write-backs with gaps",
            (0..4)
                .map(|c| Instruction::Wfbi {
                    col: c,
                    set: Set::One,
                    bank: Bank::A,
                    addr: 24 * c,
                })
                .collect(),
        ),
    ];
    for (what, mut body) in variants {
        let mut prog = Vec::new();
        emit_load_addr(&mut prog, Reg(1), 0x100);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 });
        emit_load_addr(&mut prog, Reg(2), 0x200);
        prog.push(Instruction::Ldfb { rs: Reg(2), set: Set::Zero, bank: Bank::B, words: 32, fb_addr: 0 });
        emit_load_addr(&mut prog, Reg(3), 0x300);
        prog.push(Instruction::Ldctxt { rs: Reg(3), block: Block::Column, plane: 0, word: 0, count: 1 });
        prog.append(&mut body);
        emit_load_addr(&mut prog, Reg(5), 0x400);
        prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::A, words: 32, fb_addr: 0 });
        let program = Program::new(prog);
        let schedule = BroadcastSchedule::compile(&program).unwrap();
        assert_eq!(schedule.fused_runs(), 0, "{what}: must refuse fusion");
        let u: Vec<i16> = (0..64).map(|i| (7 * i - 200) as i16).collect();
        let v: Vec<i16> = (0..64).map(|i| (-3 * i + 50) as i16).collect();
        let raw = ContextWord::two_port(AluOp::Add).encode();
        assert_three_way_identical(
            &program,
            |sys| {
                sys.mem.store_elements(0x100, &u);
                sys.mem.store_elements(0x200, &v);
                sys.mem.write_word(0x300, raw);
            },
            what,
        );
    }
}

#[test]
fn snapshot_restore_run_is_bit_identical_to_direct_run() {
    // The snapshot conformance axis (§Robustness): restoring a staged
    // system from its image and running must be indistinguishable —
    // report and full architectural state — from running the original,
    // in both DMA modes and on both the interpreter and scheduled tiers.
    // The restore target deliberately starts in the *opposite* DMA mode:
    // the image carries the mode flag.
    for_each_case("snapshot/restore == direct", 80, |rng, _seed| {
        let staging = Staging::random(rng);
        let program = random_program(rng);
        let schedule =
            BroadcastSchedule::compile(&program).expect("straight-line programs always compile");
        for async_dma in [false, true] {
            let mut direct = M1System::with_dma_mode(async_dma);
            staging.apply(&mut direct);
            let image = direct.snapshot();
            let rd = direct.run(&program);

            let mut restored = M1System::with_dma_mode(!async_dma);
            restored.restore(&image).expect("staged image restores");
            let rr = restored.run(&program);
            assert_eq!(rd.cycles, rr.cycles, "cycles (async={async_dma})");
            assert_eq!(rd.slots, rr.slots, "slots (async={async_dma})");
            assert_eq!(rd.executed, rr.executed, "executed (async={async_dma})");
            assert_systems_identical(
                &direct,
                &restored,
                &format!("restored interpreter run (async={async_dma})"),
            );

            let mut sched = M1System::with_dma_mode(!async_dma);
            sched.restore(&image).expect("staged image restores");
            let rs = sched.run_program(&program, Some(&schedule));
            assert_eq!(rd.cycles, rs.cycles, "scheduled cycles (async={async_dma})");
            assert_systems_identical(
                &direct,
                &sched,
                &format!("restored scheduled run (async={async_dma})"),
            );
        }
    });
}

#[test]
fn split_runs_through_a_snapshot_match_uninterrupted_continuation() {
    // Warm-restart fidelity: cut a random program at a random instruction
    // boundary, run the prefix, snapshot, and run the suffix on (a) the
    // original system and (b) a fresh system restored from the image.
    // Both suffix runs — including any async-DMA readiness state the
    // prefix left behind — must agree bit-for-bit. This is exactly what
    // the tile pool's supervised warm restart relies on.
    for_each_case("snapshot continuation", 60, |rng, _seed| {
        let program = random_program(rng);
        if program.instructions.len() < 4 {
            return;
        }
        let staging = Staging::random(rng);
        let k = 1 + rng.below((program.instructions.len() - 1) as u64) as usize;
        let prefix = Program::new(program.instructions[..k].to_vec());
        let suffix = Program::new(program.instructions[k..].to_vec());
        for async_dma in [false, true] {
            let mut original = M1System::with_dma_mode(async_dma);
            staging.apply(&mut original);
            original.run(&prefix);
            let image = original.snapshot();
            let ra = original.run(&suffix);

            let mut resumed = M1System::new();
            resumed.restore(&image).expect("mid-sequence image restores");
            let rb = resumed.run(&suffix);
            assert_eq!(ra.cycles, rb.cycles, "suffix cycles (k={k}, async={async_dma})");
            assert_eq!(ra.executed, rb.executed, "suffix executed (k={k}, async={async_dma})");
            assert_systems_identical(
                &original,
                &resumed,
                &format!("suffix state (k={k}, async={async_dma})"),
            );
        }
    });
}

#[test]
fn most_generated_schedules_take_the_validated_fast_path() {
    // The generator only emits in-range addresses, so every schedule must
    // validate — i.e. the unchecked-read path is what the differential
    // test above actually exercises.
    for_each_case("schedules validate", 50, |rng, _seed| {
        let program = random_program(rng);
        assert!(BroadcastSchedule::compile(&program).unwrap().is_validated());
    });
}

/// Deterministic, exactly-quantizable affine params: matrix entries are
/// multiples of 2⁻⁶ within the Q6 i8 range, translations small integers.
fn random_quantizable_params(rng: &mut Rng) -> [f32; 6] {
    let q = |rng: &mut Rng| rng.range_i64(-127, 127) as f32 / 64.0;
    [
        q(rng),
        q(rng),
        q(rng),
        q(rng),
        rng.range_i64(-100, 100) as f32,
        rng.range_i64(-100, 100) as f32,
    ]
}

fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn megakernel_tier_is_bit_identical_across_dma_modes_and_sizes() {
    // The megakernel conformance axis (§Perf, megakernel tier): for each
    // plan size covering the acceptance grid padded to whole tiles
    // (64, 512, 2176, 4096 — the pooled backend grids below cover the
    // ragged originals 500/2117 end to end), a random plan-level spec
    // runs on the interpreter, the scheduled/fused tier, and the
    // megakernel tier. All three must agree bit-for-bit on cycle reports,
    // the result window, and full architectural state, in both DMA
    // modes; divergences dump `.m1ra` artifacts like every other axis.
    use morpho::mapping::runner::stage_routine3_on;
    use morpho::mapping::{megakernel_for, MegaSpec, RESULT_ADDR};
    for &n in &[64usize, 512, 2176, 4096] {
        let cases = if n >= 2176 { 2 } else { 6 };
        for_each_case(&format!("megakernel n={n}"), cases, |rng, seed| {
            let spec = if rng.bool() {
                let ops = [AluOp::Add, AluOp::Sub, AluOp::Mul, AluOp::And, AluOp::Or, AluOp::Xor];
                MegaSpec::VecVec { n, op: ops[rng.below(ops.len() as u64) as usize] }
            } else {
                let e = |rng: &mut Rng| rng.range_i64(-128, 127) as i16;
                MegaSpec::PointTransform {
                    n,
                    m: [e(rng), e(rng), e(rng), e(rng)],
                    t: [e(rng), e(rng)],
                    shift: rng.below(7) as u8,
                }
            };
            let plan = megakernel_for(&spec).expect("whole-tile plan shapes compile");
            let program = &plan.routine.program;
            let u: Vec<i16> = (0..n).map(|_| rng.i16()).collect();
            let v: Vec<i16> = (0..n).map(|_| rng.i16()).collect();
            let stage = |sys: &mut M1System| {
                stage_routine3_on(sys, &plan.routine, &u, Some(v.as_slice()), None);
            };
            for async_dma in [false, true] {
                let mut interp = M1System::with_dma_mode(async_dma);
                stage(&mut interp);
                let ri = interp.run(program);

                let schedule =
                    BroadcastSchedule::compile(program).expect("plans are straight-line");
                let mut sched = M1System::with_dma_mode(async_dma);
                stage(&mut sched);
                let rs = sched.run_program(program, Some(&schedule));

                let mut mega = M1System::with_dma_mode(async_dma);
                stage(&mut mega);
                let rm = mega.run_megakernel(program, &plan.kernel);

                guard_differential(
                    seed,
                    &format!("megakernel vs interpreter (n={n}, async={async_dma})"),
                    || {
                        let mut fresh = M1System::with_dma_mode(async_dma);
                        stage(&mut fresh);
                        fresh.snapshot()
                    },
                    program,
                    || mega.mem.load_elements(0, 2 * MEM_WINDOW),
                    || {
                        for (tier, r) in [("scheduled", &rs), ("megakernel", &rm)] {
                            let ctx = format!("n={n} async={async_dma} {tier}");
                            assert_eq!(ri.cycles, r.cycles, "{ctx}: cycles");
                            assert_eq!(ri.slots, r.slots, "{ctx}: slots");
                            assert_eq!(ri.executed, r.executed, "{ctx}: executed");
                            assert_eq!(ri.broadcasts, r.broadcasts, "{ctx}: broadcasts");
                        }
                        // The result window lives outside MEM_WINDOW, so
                        // compare it explicitly on top of the full
                        // architectural-state sweep.
                        let want = interp.mem.load_elements(RESULT_ADDR, plan.routine.result_elems);
                        assert_eq!(
                            want,
                            sched.mem.load_elements(RESULT_ADDR, plan.routine.result_elems),
                            "scheduled result window"
                        );
                        assert_eq!(
                            want,
                            mega.mem.load_elements(RESULT_ADDR, plan.routine.result_elems),
                            "megakernel result window"
                        );
                        assert_systems_identical(&interp, &sched, "scheduled state");
                        assert_systems_identical(&interp, &mega, "megakernel state");
                    },
                );
            }
        });
    }
}

#[test]
fn megakernel_plan_requests_match_the_per_tile_decomposition() {
    // megakernel ≡ per-tile fused at the pool level: one plan-level
    // request over k tiles must transform its data exactly as k per-tile
    // requests through the scheduled/fused tier, for both spec families,
    // under a randomly chosen DMA mode (results are mode-independent).
    use morpho::coordinator::{RoutineSpec, TilePool, TileRequest};
    for_each_case("plan request == per-tile decomposition", 30, |rng, _seed| {
        let tiles = rng.range_i64(2, 9) as usize;
        let n = tiles * 64;
        let mut pool = TilePool::with_mode(1, rng.bool());
        let u: Vec<i16> = (0..n).map(|_| rng.range_i64(-2000, 2000) as i16).collect();
        let v: Vec<i16> = (0..n).map(|_| rng.range_i64(-2000, 2000) as i16).collect();

        let op = [AluOp::Add, AluOp::Sub, AluOp::Xor][rng.below(3) as usize];
        let plan = pool.run(vec![TileRequest {
            spec: RoutineSpec::VecVecPlan { n, op },
            u: u.clone(),
            v: Some(v.clone()),
        }]);
        let per = pool.run(
            u.chunks(64)
                .zip(v.chunks(64))
                .map(|(uc, vc)| TileRequest {
                    spec: RoutineSpec::VecVec { n: 64, op },
                    u: uc.to_vec(),
                    v: Some(vc.to_vec()),
                })
                .collect(),
        );
        let spliced: Vec<i16> = per.iter().flat_map(|o| o.result.iter().copied()).collect();
        assert_eq!(plan[0].result, spliced, "vecvec {op:?} n={n}");

        let e = |rng: &mut Rng| rng.range_i64(-128, 127) as i16;
        let (m, t) = ([e(rng), e(rng), e(rng), e(rng)], [e(rng), e(rng)]);
        let shift = rng.below(7) as u8;
        let plan = pool.run(vec![TileRequest {
            spec: RoutineSpec::PointTransformPlan { n, m, t, shift },
            u: u.clone(),
            v: Some(v.clone()),
        }]);
        let per = pool.run(
            u.chunks(64)
                .zip(v.chunks(64))
                .map(|(uc, vc)| TileRequest {
                    spec: RoutineSpec::PointTransform { n: 64, m, t, shift },
                    u: uc.to_vec(),
                    v: Some(vc.to_vec()),
                })
                .collect(),
        );
        // Plan layout is [all x'][all y']; per-tile layout interleaves
        // [x'; 64][y'; 64] per tile.
        let (xp, yp) = plan[0].result.split_at(n);
        for (k, o) in per.iter().enumerate() {
            let (ox, oy) = o.result.split_at(64);
            assert_eq!(&xp[k * 64..(k + 1) * 64], ox, "x' tile {k} (shift={shift})");
            assert_eq!(&yp[k * 64..(k + 1) * 64], oy, "y' tile {k} (shift={shift})");
        }
    });
}

#[test]
fn pooled_backend_matches_serial_across_shard_counts_and_sizes() {
    // The acceptance grid: shard counts {1, 2, 4, 8} × n ∈ {64, 500,
    // 2117, 4096}, byte-identical outputs and identical aggregate cycles.
    let params = [0.5, -0.25, 0.25, 0.5, 7.0, -3.0];
    for &n in &[64usize, 500, 2117, 4096] {
        let mut rng = Rng::new(0xBA5E ^ n as u64);
        let base_x: Vec<f32> = (0..n).map(|_| rng.range_i64(-2000, 2000) as f32).collect();
        let base_y: Vec<f32> = (0..n).map(|_| rng.range_i64(-2000, 2000) as f32).collect();

        let mut serial = M1SimBackend::new();
        let (mut sx, mut sy) = (base_x.clone(), base_y.clone());
        let sc = serial.apply(&params, &mut sx, &mut sy).unwrap().unwrap();

        for shards in [1usize, 2, 4, 8] {
            let mut pooled = M1SimBackend::with_shards(shards);
            let (mut px, mut py) = (base_x.clone(), base_y.clone());
            let pc = pooled.apply(&params, &mut px, &mut py).unwrap().unwrap();
            assert_bits_equal(&sx, &px, &format!("xs n={n} shards={shards}"));
            assert_bits_equal(&sy, &py, &format!("ys n={n} shards={shards}"));
            assert_eq!(
                sc.to_bits(),
                pc.to_bits(),
                "aggregate cycles n={n} shards={shards}: {sc} vs {pc}"
            );
        }
    }
}

#[test]
fn pooled_async_dma_backend_matches_serial_across_shard_counts_and_sizes() {
    // The §Perf PR 5 acceptance grid, async-DMA edition: shard counts
    // {1, 2, 4, 8} × n ∈ {64, 500, 2117, 4096} on overlapped-DMA shard
    // simulators. Outputs must equal the blocking backend's
    // byte-for-byte (DMA mode never changes results), aggregate cycles
    // must be shard-count-independent and strictly below blocking's
    // (the overlap win).
    let params = [0.5, -0.25, 0.25, 0.5, 7.0, -3.0];
    for &n in &[64usize, 500, 2117, 4096] {
        let mut rng = Rng::new(0xA57E ^ n as u64);
        let base_x: Vec<f32> = (0..n).map(|_| rng.range_i64(-2000, 2000) as f32).collect();
        let base_y: Vec<f32> = (0..n).map(|_| rng.range_i64(-2000, 2000) as f32).collect();

        let mut blocking = M1SimBackend::new();
        let (mut bx, mut by) = (base_x.clone(), base_y.clone());
        let bc = blocking.apply(&params, &mut bx, &mut by).unwrap().unwrap();

        let mut serial_async = M1SimBackend::with_config(1, true);
        let (mut sx, mut sy) = (base_x.clone(), base_y.clone());
        let sc = serial_async.apply(&params, &mut sx, &mut sy).unwrap().unwrap();
        assert_bits_equal(&bx, &sx, &format!("async vs blocking xs n={n}"));
        assert_bits_equal(&by, &sy, &format!("async vs blocking ys n={n}"));
        assert!(sc < bc, "n={n}: async cycles/point {sc} !< blocking {bc}");

        for shards in [1usize, 2, 4, 8] {
            let mut pooled = M1SimBackend::with_config(shards, true);
            let (mut px, mut py) = (base_x.clone(), base_y.clone());
            let pc = pooled.apply(&params, &mut px, &mut py).unwrap().unwrap();
            assert_bits_equal(&sx, &px, &format!("async xs n={n} shards={shards}"));
            assert_bits_equal(&sy, &py, &format!("async ys n={n} shards={shards}"));
            assert_eq!(
                sc.to_bits(),
                pc.to_bits(),
                "async aggregate cycles n={n} shards={shards}: {sc} vs {pc}"
            );
        }
    }
}

#[test]
fn pooled_backend_randomized_conformance_against_serial() {
    // Random quantizable transforms over random coordinate sets: serial
    // and pooled execution agree bit-for-bit, including the padded tail
    // tile of non-multiple-of-64 sizes.
    let mut serial = M1SimBackend::new();
    let mut pooled = M1SimBackend::with_shards(4);
    for_each_case("pooled == serial", 200, |rng, _seed| {
        let n = rng.range_i64(1, 300) as usize;
        let params = random_quantizable_params(rng);
        let base_x: Vec<f32> = (0..n).map(|_| rng.range_i64(-4000, 4000) as f32).collect();
        let base_y: Vec<f32> = (0..n).map(|_| rng.range_i64(-4000, 4000) as f32).collect();
        let (mut sx, mut sy) = (base_x.clone(), base_y.clone());
        let sc = serial.apply(&params, &mut sx, &mut sy).unwrap();
        let (mut px, mut py) = (base_x, base_y);
        let pc = pooled.apply(&params, &mut px, &mut py).unwrap();
        assert_bits_equal(&sx, &px, "xs");
        assert_bits_equal(&sy, &py, "ys");
        match (sc, pc) {
            (Some(s), Some(p)) => assert_eq!(s.to_bits(), p.to_bits(), "cycles"),
            (s, p) => assert_eq!(s.is_none(), p.is_none(), "fallback disagreement"),
        }
    });
}

#[test]
fn unquantizable_fallback_is_identical_across_shard_counts() {
    // Scale 100× exceeds the Q6 i8 range, and coordinates past the
    // headroom limit force the native path too; both fallbacks must
    // behave identically for every shard count (native result, no
    // simulated cycles).
    for (params, xs) in [
        ([100.0f32, 0.0, 0.0, 100.0, 0.0, 0.0], vec![1.0f32, 2.0, 3.0]),
        ([1.0, 0.0, 0.0, 1.0, 1.0, 1.0], vec![9000.0f32, 1.0]),
    ] {
        let ys = vec![1.0f32; xs.len()];
        let mut want_x = xs.clone();
        let mut want_y = ys.clone();
        apply_native(&params, &mut want_x, &mut want_y);
        for shards in [1usize, 2, 4, 8] {
            let mut backend = M1SimBackend::with_shards(shards);
            let (mut px, mut py) = (xs.clone(), ys.clone());
            let cycles = backend.apply(&params, &mut px, &mut py).unwrap();
            assert_eq!(cycles, None, "shards={shards}");
            assert_bits_equal(&want_x, &px, "fallback xs");
            assert_bits_equal(&want_y, &py, "fallback ys");
        }
    }
}
