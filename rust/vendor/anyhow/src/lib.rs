//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! This workspace builds with no network access, so the real crates.io
//! `anyhow` cannot be fetched. This vendored shim implements exactly the
//! surface the `morpho` crate uses — `Error`, `Result`, the `anyhow!` /
//! `bail!` / `ensure!` macros and the `Context` extension trait — with
//! the same observable behaviour for display formatting (`{}` shows the
//! outermost message, `{:#}` and `{:?}` show the whole cause chain
//! joined by `": "`). Downcasting and backtraces are intentionally not
//! supported; nothing in this workspace uses them.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error: an outermost message plus the flattened messages
/// of its source chain.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message (used by [`Context`]).
    fn wrap(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    fn from_std(err: &(dyn StdError + 'static)) -> Error {
        let mut chain = vec![err.to_string()];
        let mut cur = err.source();
        while let Some(src) = cur {
            chain.push(src.to_string());
            cur = src.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// Extension trait attaching context messages to `Result` / `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Result::<(), _>::Err(io_err()).context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "missing thing");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(n: i32) -> Result<i32> {
            ensure!(n >= 0, "negative input {n}");
            if n > 100 {
                bail!("too large: {n}");
            }
            Ok(n)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too large: 101");
        let e = anyhow!("plain {}", "message");
        assert_eq!(format!("{e}"), "plain message");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| "nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
        assert_eq!(Some(7u8).context("unused").unwrap(), 7);
    }
}
