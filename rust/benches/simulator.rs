//! Bench: simulator hot paths — RC-array broadcast throughput, full
//! routine execution rate, x86 interpreter throughput. These are the
//! numbers the §Perf optimization pass tracks.
//!
//! Besides the human-readable stdout report, the run writes
//! `BENCH_simulator.json` (override the path with `BENCH_JSON`) so the
//! perf trajectory can be tracked across PRs without scraping stdout.

use morpho::baselines::routines as x86;
use morpho::baselines::Cpu;
use morpho::benchkit::{bench, section, Measurement};
use morpho::coordinator::backend::{Backend, M1SimBackend};
use morpho::mapping::{
    megakernel_for, run_plan,
    runner::{run_routine3_with, run_routine_on},
    MegaSpec, PointTransformMapping, StreamedTiledMapping, VecVecMapping,
};
use morpho::morphosys::rc_array::{BroadcastMode, ContextWord, MuxASel, RcArray};
use morpho::morphosys::{AluOp, BroadcastSchedule, M1System};

/// One machine-readable result row.
struct JsonRow {
    bench: String,
    mean_ns: f64,
    iters: u64,
    unit: &'static str,
    throughput: f64,
}

fn row(m: &Measurement, unit: &'static str, throughput: f64) -> JsonRow {
    JsonRow {
        bench: m.name.clone(),
        mean_ns: m.mean.as_secs_f64() * 1e9,
        iters: m.iters,
        unit,
        throughput,
    }
}

/// Record a points/s measurement: print the human-readable line (with an
/// optional speed-up ratio against a reference measurement) and push the
/// machine-readable row. Every simulated-points bench goes through here so
/// the stdout format and the JSON row stay in lock-step.
fn record_points(
    rows: &mut Vec<JsonRow>,
    m: &Measurement,
    points: f64,
    baseline: Option<(&Measurement, &str)>,
) {
    match baseline {
        Some((b, label)) => println!(
            "  → {:.2} M simulated-points/s ({:.2}× vs {})",
            m.throughput(points) / 1e6,
            b.mean.as_secs_f64() / m.mean.as_secs_f64(),
            label,
        ),
        None => println!("  → {:.2} M simulated-points/s", m.throughput(points) / 1e6),
    }
    rows.push(row(m, "points_per_s", m.throughput(points)));
}

fn write_json(rows: &[JsonRow]) {
    let path =
        std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_simulator.json".to_string());
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"bench\": \"{}\", \"mean_ns\": {:.1}, \"iters\": {}, \"unit\": \"{}\", \"throughput\": {:.1}}}{}\n",
            r.bench.replace('"', "'"),
            r.mean_ns,
            r.iters,
            r.unit,
            r.throughput,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("]\n");
    match morpho::benchkit::write_atomic(&path, &out) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let mut rows = Vec::new();

    section("RC array broadcast (the innermost simulator loop)");
    let mut arr = RcArray::new();
    let cw = ContextWord::two_port(AluOp::Add);
    let a = [1i16; 8];
    let b = [2i16; 8];
    let m = bench("column broadcast (8 cells)", || {
        for col in 0..8 {
            arr.broadcast(BroadcastMode::Column, col, &cw, &a, &b);
        }
    });
    println!("  → {:.1} M cell-ops/s", m.throughput(64.0) / 1e6);
    rows.push(row(&m, "cell_ops_per_s", m.throughput(64.0)));

    // The general (interconnect) operand path, to track the non-fast-path
    // cost separately from the dominant bus/bus case.
    let mut west = ContextWord::two_port(AluOp::Add);
    west.mux_a = MuxASel::West;
    let m = bench("column broadcast (West-neighbour path)", || {
        for col in 0..8 {
            arr.broadcast(BroadcastMode::Column, col, &west, &a, &b);
        }
    });
    println!("  → {:.1} M cell-ops/s", m.throughput(64.0) / 1e6);
    rows.push(row(&m, "cell_ops_per_s", m.throughput(64.0)));

    section("full M1 routine simulation rate");
    let routine = VecVecMapping { n: 64, op: AluOp::Add }.compile();
    let u: Vec<i16> = (0..64).collect();
    let v = vec![9i16; 64];
    let mut sys = M1System::new();
    let m = bench("translation-64 routine (reused system)", || {
        sys.reset_chip();
        std::hint::black_box(run_routine_on(&mut sys, &routine, &u, Some(&v)));
    });
    println!(
        "  → {:.1}k routines/s, {:.1} M simulated-elements/s",
        1.0 / m.mean.as_secs_f64() / 1e3,
        m.throughput(64.0) / 1e6
    );
    rows.push(row(&m, "routines_per_s", 1.0 / m.mean.as_secs_f64()));

    let pt = PointTransformMapping { n: 64, m: [0, -64, 64, 0], t: [3, -2], shift: 6 }.compile();
    let mut sys2 = M1System::new();
    let m = bench("point-transform-64 routine (8 broadcasts/column)", || {
        sys2.reset_chip();
        std::hint::black_box(run_routine_on(&mut sys2, &pt, &u, Some(&v)));
    });
    record_points(&mut rows, &m, 64.0, None);

    section("sharded tile pool (translation, 2117-point jobs)");
    // The §Perf doc's motivating job size: 2 117 points = 34 M1 tiles.
    // Same integer-translation transform and fresh inputs per iteration
    // for both backends, so the delta is purely the shard fan-out.
    let params = [1.0f32, 0.0, 0.0, 1.0, 7.0, -3.0];
    let base_xs: Vec<f32> = (0..2117).map(|i| ((i % 4001) as f32) - 2000.0).collect();
    let base_ys: Vec<f32> = (0..2117).map(|i| ((i % 1999) as f32) - 999.0).collect();
    let mut xs = base_xs.clone();
    let mut ys = base_ys.clone();
    let mut serial = M1SimBackend::new();
    let m_serial = bench("serial translation-2117 (shards=1)", || {
        xs.copy_from_slice(&base_xs);
        ys.copy_from_slice(&base_ys);
        std::hint::black_box(serial.apply(&params, &mut xs, &mut ys).unwrap());
    });
    record_points(&mut rows, &m_serial, 2117.0, None);
    let mut pooled = M1SimBackend::with_shards(4);
    let m_pooled = bench("pooled translation-2117 (shards=4)", || {
        xs.copy_from_slice(&base_xs);
        ys.copy_from_slice(&base_ys);
        std::hint::black_box(pooled.apply(&params, &mut xs, &mut ys).unwrap());
    });
    record_points(&mut rows, &m_pooled, 2117.0, Some((&m_serial, "serial")));

    section("fused tile-kernel tier (vecvec translation, 2117-point tile plan)");
    // 2 117 elements decompose into 33 full 64-point vector-vector tiles
    // plus one 8-point tail tile (5 live elements, zero-padded) — the
    // same whole-tile planning the coordinator makes. Both rows run the
    // identical tile plan on one reused system; the only difference is
    // the schedule tier: `compile` fuses the broadcast/write-back runs
    // into SIMD lane-kernel loops, `compile_unfused` pins the PR 2
    // step-per-instruction scheduled path.
    let full = VecVecMapping { n: 64, op: AluOp::Add }.compile();
    let tail = VecVecMapping { n: 8, op: AluOp::Add }.compile();
    let full_fused = BroadcastSchedule::compile(&full.program).unwrap();
    let full_sched = BroadcastSchedule::compile_unfused(&full.program).unwrap();
    let tail_fused = BroadcastSchedule::compile(&tail.program).unwrap();
    let tail_sched = BroadcastSchedule::compile_unfused(&tail.program).unwrap();
    assert!(full_fused.fused_runs() > 0, "translation tile must fuse");
    assert_eq!(full_sched.fused_runs(), 0, "baseline must stay unfused");
    let tu: Vec<i16> = (0..2117).map(|i| (i % 251) as i16 - 125).collect();
    let tv: Vec<i16> = (0..2117).map(|i| (i % 83) as i16 - 41).collect();
    let mut tail_u = [0i16; 8];
    let mut tail_v = [0i16; 8];
    tail_u[..5].copy_from_slice(&tu[2112..]);
    tail_v[..5].copy_from_slice(&tv[2112..]);
    let mut sys3 = M1System::new();
    let run_tile_plan = |sys: &mut M1System, full_s: &BroadcastSchedule, tail_s: &BroadcastSchedule| {
        for t in 0..33 {
            sys.reset_chip();
            std::hint::black_box(run_routine3_with(
                sys,
                &full,
                &tu[t * 64..(t + 1) * 64],
                Some(&tv[t * 64..(t + 1) * 64]),
                None,
                Some(full_s),
            ));
        }
        sys.reset_chip();
        std::hint::black_box(run_routine3_with(
            sys,
            &tail,
            &tail_u,
            Some(&tail_v),
            None,
            Some(tail_s),
        ));
    };
    let m_sched = bench("scheduled translation-2117 (shards=1)", || {
        run_tile_plan(&mut sys3, &full_sched, &tail_sched)
    });
    record_points(&mut rows, &m_sched, 2117.0, None);
    let m_fused = bench("fused translation-2117 (shards=1)", || {
        run_tile_plan(&mut sys3, &full_fused, &tail_fused)
    });
    record_points(&mut rows, &m_fused, 2117.0, Some((&m_sched, "scheduled")));

    section("async-DMA streamed tier (set ping-pong, 2117-point covering plan)");
    // The paper's headline large-n scenario: a 2 117-point translation
    // streamed through the two frame-buffer sets under async DMA (34
    // ping-ponged 64-point tiles — 2 176 elements, tail zero-padded, the
    // same whole-tile covering the coordinator plans). Both rows run the
    // identical routine on the same async-DMA system; the only
    // difference is the executor tier: the interpreter (the pre-§Perf-
    // PR 5 path for async DMA) vs the compiled schedule with precomputed
    // async accounting and fused SIMD runs.
    let streamed = StreamedTiledMapping { n: 2176, op: AluOp::Add }.compile();
    let streamed_sched = BroadcastSchedule::compile(&streamed.program).unwrap();
    assert!(streamed_sched.fused_runs() > 0, "streamed tiles must fuse");
    let mut su = vec![0i16; 2176];
    let mut sv = vec![0i16; 2176];
    for (i, (u, v)) in su.iter_mut().zip(sv.iter_mut()).take(2117).enumerate() {
        *u = (i % 251) as i16 - 125;
        *v = (i % 83) as i16 - 41;
    }
    let mut sys4 = M1System::new().with_async_dma();
    // The two tiers must agree bit-for-bit on the async report before we
    // time them.
    sys4.reset_chip();
    let ri = run_routine3_with(&mut sys4, &streamed, &su, Some(&sv), None, None).report;
    sys4.reset_chip();
    let rs_out =
        run_routine3_with(&mut sys4, &streamed, &su, Some(&sv), None, Some(&streamed_sched));
    let rs = &rs_out.report;
    assert_eq!(
        (ri.cycles, ri.slots, ri.executed, ri.broadcasts),
        (rs.cycles, rs.slots, rs.executed, rs.broadcasts),
        "async accounting must match the interpreter"
    );
    let m_sa_interp = bench("streamed-async translation-2117 (interpreter)", || {
        sys4.reset_chip();
        std::hint::black_box(run_routine3_with(&mut sys4, &streamed, &su, Some(&sv), None, None));
    });
    record_points(&mut rows, &m_sa_interp, 2117.0, None);
    let m_sa_sched = bench("streamed-async translation-2117 (scheduled)", || {
        sys4.reset_chip();
        std::hint::black_box(run_routine3_with(
            &mut sys4,
            &streamed,
            &su,
            Some(&sv),
            None,
            Some(&streamed_sched),
        ));
    });
    record_points(&mut rows, &m_sa_sched, 2117.0, Some((&m_sa_interp, "interpreter")));

    section("megakernel tier (plan-level compile, 2117-point covering plan)");
    // The same 2 176-element async-DMA covering plan as the streamed rows
    // above, but lowered by the request-level megakernel compiler: context
    // words are loaded once for the whole request, the DMA streams are
    // batched across tile boundaries under the set ping-pong, and every
    // tile's broadcast + write-back runs as one fused kernel. The compiled
    // plan comes out of the process-wide cache keyed by (transform shape,
    // n) — the batched row below reuses the same compilation, which is
    // exactly what the coordinator's Batcher does for a window of
    // same-shape requests.
    let mega = megakernel_for(&MegaSpec::VecVec { n: 2176, op: AluOp::Add })
        .expect("2176-element vecvec plan must be megakernel-compilable");
    // The megakernel must agree bit-for-bit with the scheduled tier on the
    // result vector before we time it.
    sys4.reset_chip();
    let rm = run_plan(&mut sys4, &mega, &su, Some(&sv));
    assert_eq!(
        rm.result, rs_out.result,
        "megakernel result must match the scheduled tier"
    );
    let m_mega = bench("megakernel translation-2117", || {
        sys4.reset_chip();
        std::hint::black_box(run_plan(&mut sys4, &mega, &su, Some(&sv)));
    });
    record_points(&mut rows, &m_mega, 2117.0, Some((&m_sa_sched, "scheduled")));
    // A Batcher-shaped burst: eight same-shape requests dispatched through
    // the one cached plan, the per-request compile cost fully amortized.
    let m_mega8 = bench("megakernel translation-2117 (batched x8)", || {
        for _ in 0..8 {
            sys4.reset_chip();
            std::hint::black_box(run_plan(&mut sys4, &mega, &su, Some(&sv)));
        }
    });
    record_points(&mut rows, &m_mega8, 8.0 * 2117.0, None);

    section("x86 baseline interpreter");
    let ub: Vec<i16> = (0..64).collect();
    let vb = vec![1i16; 64];
    for cpu in Cpu::ALL {
        let m = bench(&format!("{} translation-64 listing", cpu.name()), || {
            std::hint::black_box(x86::run_translation(cpu, &ub, &vb));
        });
        println!("  → {:.1} M interpreted-instr/s", m.throughput(9.0 * 64.0) / 1e6);
        rows.push(row(&m, "instr_per_s", m.throughput(9.0 * 64.0)));
    }
    let m = bench("80486 matmul-8x8 listing", || {
        std::hint::black_box(x86::run_matmul(Cpu::I486, 8, &ub, &vb));
    });
    println!("  → {:.2}k matmuls/s", 1.0 / m.mean.as_secs_f64() / 1e3);
    rows.push(row(&m, "matmuls_per_s", 1.0 / m.mean.as_secs_f64()));

    write_json(&rows);
}
