//! Bench: regenerate Figures 9–16 (cycles and cycles/element for the 8-
//! and 64-element translation and scaling algorithms across M1 / 80486 /
//! 80386), plus a size sweep that extends the figures beyond the paper's
//! two sizes — the ablation showing where the M1's advantage comes from.

use morpho::baselines::routines as x86;
use morpho::baselines::Cpu;
use morpho::benchkit::section;
use morpho::mapping::{runner::run_routine, MappingPlan, VecVecMapping};
use morpho::morphosys::AluOp;
use morpho::perf::{figure, render_figure};

fn main() {
    for num in 9..=16 {
        let (title, rows, per_elem) = figure(num);
        println!("{}", render_figure(&title, &rows, per_elem));
    }

    section("extension: translation cycles/element vs vector size (not in paper)");
    println!("{:>4} {:>10} {:>10} {:>10} {:>12}", "n", "M1", "80486", "80386", "M1 speedup");
    for n in [8usize, 16, 24, 32, 40, 48, 56, 64] {
        let u: Vec<i16> = (0..n as i16).collect();
        let v = vec![3i16; n];
        let m1 = run_routine(&VecVecMapping { n, op: AluOp::Add }.compile(), &u, Some(&v))
            .report
            .cycles;
        let c486 = x86::run_translation(Cpu::I486, &u, &v).1.cycles;
        let c386 = x86::run_translation(Cpu::I386, &u, &v).1.cycles;
        println!(
            "{:>4} {:>10.3} {:>10.3} {:>10.3} {:>11.2}x",
            n,
            m1 as f64 / n as f64,
            c486 as f64 / n as f64,
            c386 as f64 / n as f64,
            c486 as f64 / m1 as f64
        );
    }

    section("ablation: where do the M1's cycles go? (phase breakdown)");
    println!("{:>4} {:>8} {:>8} {:>9} {:>8} {:>14}", "n", "load", "config", "compute", "store", "compute-frac");
    for n in [8usize, 16, 32, 64] {
        let r = VecVecMapping { n, op: AluOp::Add }.compile();
        let plan = MappingPlan::analyze(&r.program);
        println!(
            "{:>4} {:>8} {:>8} {:>9} {:>8} {:>13.1}%",
            n,
            plan.load,
            plan.config,
            plan.compute,
            plan.store,
            100.0 * plan.compute_fraction()
        );
    }
    println!(
        "\nThe broadcasts themselves are a small fraction of the budget: the M1's win\n\
         comes from feeding 8 ALUs per cycle during them, while DMA dominates both ends."
    );
}
