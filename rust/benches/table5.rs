//! Bench: regenerate Table 5 end-to-end and time every cell — the M1
//! simulator running the paper's mappings and the x86 models running the
//! paper's listings. This is the headline-reproduction bench: it prints
//! the full measured-vs-paper table and the simulation cost of each cell.

use morpho::baselines::routines as x86;
use morpho::baselines::Cpu;
use morpho::benchkit::{bench, section};
use morpho::mapping::{runner::run_routine, MatMulMapping, VecScalarMapping, VecVecMapping};
use morpho::morphosys::AluOp;
use morpho::perf::{render_table, table5};

fn main() {
    section("Table 5 — full regeneration (measured vs paper)");
    println!("{}", render_table("Table 5", &table5()));

    section("simulation cost per Table 5 cell (host-side wall time)");
    let u64v: Vec<i16> = (0..64).collect();
    let v64: Vec<i16> = vec![7; 64];
    let u8v: Vec<i16> = (0..8).collect();
    let v8: Vec<i16> = vec![7; 8];

    let t64 = VecVecMapping { n: 64, op: AluOp::Add }.compile();
    bench("M1 translation-64 (96 M1 cycles)", || {
        std::hint::black_box(run_routine(&t64, &u64v, Some(&v64)));
    });
    let t8 = VecVecMapping { n: 8, op: AluOp::Add }.compile();
    bench("M1 translation-8 (21 M1 cycles)", || {
        std::hint::black_box(run_routine(&t8, &u8v, Some(&v8)));
    });
    let s64 = VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile();
    bench("M1 scaling-64 (55 M1 cycles)", || {
        std::hint::black_box(run_routine(&s64, &u64v, None));
    });
    let rot = MatMulMapping { dim: 8, a: vec![1; 64], shift: 0 }.compile();
    bench("M1 rotation-I 8x8 matmul", || {
        std::hint::black_box(run_routine(&rot, &u64v, None));
    });

    for cpu in Cpu::ALL {
        bench(&format!("{} translation-64 listing", cpu.name()), || {
            std::hint::black_box(x86::run_translation(cpu, &u64v, &v64));
        });
    }
    bench("80486 rotation 8x8 matmul listing", || {
        std::hint::black_box(x86::run_matmul(Cpu::I486, 8, &u64v, &v64.repeat(1)));
    });

    section("speedup summary (M1 cycles vs baseline cycles)");
    for block in table5() {
        let m1 = &block[0];
        for other in &block[1..] {
            println!(
                "{:<14} n={:<3} M1 {:>6} vs {:<8} {:>7} cycles → speedup {:>7.2}",
                m1.algorithm,
                m1.n,
                m1.cycles,
                other.system,
                other.cycles,
                other.cycles as f64 / m1.cycles as f64
            );
        }
    }
}
