//! Bench: the serving layer — request→response latency and sustained
//! point throughput per backend, and the effect of dynamic batching.

use std::sync::Arc;
use std::time::{Duration, Instant};

use morpho::benchkit::{bench, section};
use morpho::coordinator::{
    BackendChoice, BatcherConfig, Coordinator, CoordinatorConfig,
};
use morpho::graphics::Transform;

fn coordinator(backend: BackendChoice, max_wait_us: u64) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        backend,
        workers: 2,
        batcher: BatcherConfig {
            max_wait: Duration::from_micros(max_wait_us),
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap()
}

fn round_trip(c: &Coordinator, n: usize) {
    let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let ys = vec![1.0f32; n];
    let resp = c
        .transform_blocking(xs, ys, vec![Transform::Translate { tx: 1.0, ty: 2.0 }])
        .unwrap();
    std::hint::black_box(resp);
}

fn throughput(c: &Arc<Coordinator>, clients: usize, reqs_per_client: usize, n: usize) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let c = c.clone();
            std::thread::spawn(move || {
                for _ in 0..reqs_per_client {
                    round_trip(&c, n);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (clients * reqs_per_client * n) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    section("single-request round-trip latency (64-point tile)");
    for backend in [BackendChoice::Native, BackendChoice::M1Sim, BackendChoice::Xla] {
        let c = coordinator(backend, 100);
        bench(&format!("{backend:?} round-trip 64 pts"), || round_trip(&c, 64));
        c.shutdown();
    }

    section("sustained throughput (4 clients × 4096-point requests)");
    for backend in [BackendChoice::Native, BackendChoice::M1Sim, BackendChoice::Xla] {
        let c = Arc::new(coordinator(backend, 200));
        let tput = throughput(&c, 4, 30, 4096);
        let m = c.metrics();
        println!(
            "{:<10} {:>10.2} M points/s   (jobs={} mean_batch={:.0}pts exec p50={}µs)",
            format!("{backend:?}"),
            tput / 1e6,
            m.jobs,
            m.mean_batch_points(),
            m.execute_p50_us
        );
    }

    section("dynamic batching ablation (100 × 8-pt same-transform requests)");
    for (label, max_wait_us) in [("batching ON  (2ms window)", 2000u64), ("batching OFF (0 window)", 0)] {
        let c = Arc::new(coordinator(BackendChoice::Native, max_wait_us));
        let receivers: Vec<_> = (0..100)
            .map(|i| {
                c.submit(
                    vec![i as f32; 8],
                    vec![0.0; 8],
                    vec![Transform::Scale { sx: 2.0, sy: 2.0 }],
                )
                .unwrap()
            })
            .collect();
        for rx in receivers {
            rx.recv().unwrap().expect("no TTLs in this ablation, nothing is shed");
        }
        let m = c.metrics();
        println!(
            "{label}: requests={} jobs={} mean_batch={:.1}pts",
            m.requests,
            m.jobs,
            m.mean_batch_points()
        );
    }
}
