//! Bench: design-choice ablations DESIGN.md calls out.
//!
//! 1. Double buffering (the M1's two FB sets) — streamed+async vs naive
//!    blocking schedules, multi-tile workloads.
//! 2. Baseline headroom — the paper's looped x86 listing vs an unrolled
//!    variant vs the Pentium-scheduled one.
//! 3. The extended linear-algebra library (dot/reduce/SAXPY/matvec)
//!    against per-element x86 loop bounds.

use morpho::baselines::routines as x86;
use morpho::baselines::Cpu;
use morpho::benchkit::section;
use morpho::mapping::{
    runner::{run_routine, run_routine_async},
    DotProductMapping, MatVecMapping, SaxpyMapping, TiledVecVecMapping, VecReduceMapping,
    VecVecMapping,
};
use morpho::morphosys::AluOp;

fn main() {
    section("ablation 1: frame-buffer double buffering (simulated M1 cycles)");
    println!(
        "{:>6} {:>12} {:>14} {:>16} {:>9}",
        "n", "naive+sync", "naive+async", "streamed+async", "gain"
    );
    for n in [64usize, 128, 256, 512, 1024] {
        let u: Vec<i16> = (0..n as i16).collect();
        let v = vec![1i16; n];
        let naive = TiledVecVecMapping { n, op: AluOp::Add, streamed: false }.compile();
        let streamed = TiledVecVecMapping { n, op: AluOp::Add, streamed: true }.compile();
        // The thread-local runners: blocking and async-DMA systems reused
        // across rows, both riding the scheduled/fused tier (§Perf PR 5).
        let ns = run_routine(&naive, &u, Some(&v)).report.cycles;
        let na = run_routine_async(&naive, &u, Some(&v)).report.cycles;
        let sa = run_routine_async(&streamed, &u, Some(&v)).report.cycles;
        println!(
            "{:>6} {:>12} {:>14} {:>16} {:>8.1}%",
            n,
            ns,
            na,
            sa,
            100.0 * (1.0 - sa as f64 / ns as f64)
        );
    }

    section("ablation 2: baseline optimization headroom (cycles, 64 elements)");
    let u: Vec<i16> = (0..64).collect();
    let v = vec![1i16; 64];
    let m1 = run_routine(&VecVecMapping { n: 64, op: AluOp::Add }.compile(), &u, Some(&v))
        .report
        .cycles;
    for cpu in Cpu::ALL {
        let looped = x86::run_translation(cpu, &u, &v).1.cycles;
        let unrolled = x86::run_translation_unrolled(cpu, &u, &v).1.cycles;
        let sched = x86::run_translation_scheduled(cpu, &u, &v).1.cycles;
        println!(
            "{:<8} looped {:>6}  unrolled {:>6}  scheduled {:>6}   (M1 {} → best-case speedup {:.2}x)",
            cpu.name(),
            looped,
            unrolled,
            sched,
            m1,
            unrolled.min(sched) as f64 / m1 as f64
        );
    }

    section("ablation 3: extended linear-algebra mappings (M1 cycles)");
    let n = 64;
    let dot = run_routine(&DotProductMapping { n }.compile(), &u, Some(&v)).report.cycles;
    let red = run_routine(&VecReduceMapping { n }.compile(), &u, None).report.cycles;
    let sax = run_routine(&SaxpyMapping { n, a: 3 }.compile(), &u, Some(&v)).report.cycles;
    let mv = MatVecMapping { dim: 8, a: vec![1; 64] };
    let x: Vec<i16> = (0..8).collect();
    let mvc = run_routine(&mv.compile(), &mv.stage_input(&x), None).report.cycles;
    println!("dot-64     {dot:>5} cycles   ({:.2} cycles/element)", dot as f64 / 64.0);
    println!("reduce-64  {red:>5} cycles   ({:.2} cycles/element)", red as f64 / 64.0);
    println!("saxpy-64   {sax:>5} cycles   ({:.2} cycles/element)", sax as f64 / 64.0);
    println!("matvec-8x8 {mvc:>5} cycles");
    // The x86 486 lower bound for dot-64 (2 loads + IMUL + add + loop ≈ 25/el).
    println!(
        "vs a 486 dot-product loop lower bound ≈ {} cycles → ≥{:.0}x speedup",
        64 * 25,
        (64.0 * 25.0) / dot as f64
    );
}
