//! Bench: coordinator capacity under the loadgen scenarios — the
//! serving-layer counterpart to `benches/simulator.rs`. Runs a short
//! closed-loop saturation probe and a burst/shedding probe on the sharded
//! M1-simulator backend and writes `BENCH_coordinator.json` (override the
//! path with `BENCH_COORD_JSON`), so requests/sec, latency quantiles and
//! shed counts become part of the machine-readable cross-PR trajectory.

use std::time::Duration;

use morpho::benchkit::section;
use morpho::loadgen::{self, scenario, RouterScenario, TransportKind};

fn main() {
    let mut reports = Vec::new();

    section("closed-loop capacity (smoke scenario, shards=2)");
    let mut smoke = scenario::by_name("smoke").expect("smoke scenario");
    smoke.duration = Duration::from_secs(2);
    let r = loadgen::run_scenario(&smoke).expect("run smoke");
    println!("{}", r.render());
    reports.push(r);

    section("transport tax (steady scenario, in-process vs loopback TCP)");
    // The §Scale acceptance bar reads these two rows: loopback p99 is
    // expected within ~15% of in-process on `steady` (the wire adds
    // framing + two socket hops, not contention).
    for transport in [TransportKind::InProcess, TransportKind::Tcp] {
        let mut steady = scenario::by_name("steady").expect("steady scenario");
        steady.duration = Duration::from_secs(2);
        let r = loadgen::run_scenario(&steady.with_transport(transport)).expect("run steady");
        println!("{}", r.render());
        reports.push(r);
    }

    section("burst absorption & shedding (burst scenario, fast-reject + TTL)");
    let mut burst = scenario::by_name("burst").expect("burst scenario");
    burst.duration = Duration::from_secs(2);
    let r = loadgen::run_scenario(&burst).expect("run burst");
    println!("{}", r.render());
    reports.push(r);

    section("mixed 2D/3D workload (mixed scenario, full size ladder, shards=4)");
    let mut mixed = scenario::by_name("mixed").expect("mixed scenario");
    mixed.duration = Duration::from_secs(2);
    let r = loadgen::run_scenario(&mixed).expect("run mixed");
    println!("{}", r.render());
    reports.push(r);

    section("batch-window A/B (mixed workload: static extremes vs adaptive)");
    // The adaptive-batching bar reads these three rows plus the `mixed`
    // row above: the adaptive controller's throughput should be no worse
    // than either static extreme of its own band.
    for name in ["mixed-window-min", "mixed-window-max", "mixed-adaptive"] {
        let mut sc = scenario::by_name(name).expect("A/B scenario");
        sc.duration = Duration::from_secs(2);
        let r = loadgen::run_scenario(&sc).expect("run A/B scenario");
        println!("{}", r.render());
        reports.push(r);
    }

    section("two-lane priority serving (lanes scenario: bulk bursts vs interactive TTLs)");
    let mut lanes = scenario::by_name("lanes").expect("lanes scenario");
    lanes.duration = Duration::from_secs(2);
    let r = loadgen::run_scenario(&lanes).expect("run lanes");
    println!("{}", r.render());
    reports.push(r);

    section("degraded capacity under seeded fault injection (chaos scenario)");
    let chaos = scenario::by_name("chaos").expect("chaos scenario");
    let r = loadgen::run_scenario(&chaos).expect("run chaos");
    println!("{}", r.render());
    reports.push(r);

    section("router scaling (steady over TCP through the front-end, 1 vs 2 backends)");
    // The §Scale router bar reads these rows: with backends that saturate
    // on CPU, two of them behind the router should clear ≥1.5× the
    // single-backend tcp steady throughput (least-depth balancing pays
    // for the extra hop).
    for backends in [1usize, 2] {
        let mut steady = scenario::by_name("steady").expect("steady scenario");
        steady.duration = Duration::from_secs(2);
        let mut sc = steady.with_transport(TransportKind::Tcp);
        sc.name = if backends == 1 { "steady-router1" } else { "steady-router2" };
        sc.router = Some(RouterScenario { backends, kill_seed: None });
        let r = loadgen::run_scenario(&sc).expect("run routed steady");
        println!("{}", r.render());
        reports.push(r);
    }

    section("mid-run failover (failover scenario: kill + restart one backend)");
    let failover = scenario::by_name("failover").expect("failover scenario");
    let r = loadgen::run_scenario(&failover).expect("run failover");
    println!("{}", r.render());
    reports.push(r);

    let path = loadgen::report::default_path();
    match loadgen::report::write_reports(&reports, &path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
