//! Bench: the PJRT/XLA hot path — per-artifact execution latency, tile-
//! size scaling, and the fused 3-stage pipeline vs three separate calls
//! (the L2 fusion win).

use morpho::benchkit::{bench, section};
use morpho::runtime::Executor;

fn main() {
    let exec = Executor::discover().expect("run `make artifacts` first");
    println!("platform: {}", exec.platform());
    let names: Vec<String> = exec.registry().names().map(String::from).collect();
    exec.warm_up(names.iter().map(String::as_str)).unwrap();

    let params = [0.8f32, -0.6, 0.6, 0.8, 3.0, -1.0];

    section("affine tile latency vs size");
    for n in [64usize, 1024, 4096] {
        let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let ys: Vec<f32> = vec![1.0; n];
        let name = format!("affine{n}");
        let m = bench(&format!("{name} ({n} pts)"), || {
            std::hint::black_box(exec.run_f32(&name, &[&xs, &ys, &params]).unwrap());
        });
        println!("  → {:.2} M points/s", m.throughput(n as f64) / 1e6);
    }

    section("translate / scale artifacts (the paper's two §5 routines)");
    let u: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let v = vec![2.0f32; 64];
    bench("translate64", || {
        std::hint::black_box(exec.run_f32("translate64", &[&u, &v]).unwrap());
    });
    bench("scale64", || {
        std::hint::black_box(exec.run_f32("scale64", &[&u, &[5.0f32]]).unwrap());
    });
    let u1k: Vec<f32> = (0..1024).map(|i| i as f32).collect();
    let v1k = vec![2.0f32; 1024];
    bench("translate1024", || {
        std::hint::black_box(exec.run_f32("translate1024", &[&u1k, &v1k]).unwrap());
    });

    section("L2 fusion: pipeline3 artifact vs 3 affine1024 calls");
    let xs: Vec<f32> = (0..1024).map(|i| (i % 97) as f32).collect();
    let ys: Vec<f32> = (0..1024).map(|i| (i % 31) as f32).collect();
    let p0 = [2.0f32, 0.0, 0.0, 2.0, 0.0, 0.0];
    let p1 = [0.8f32, -0.6, 0.6, 0.8, 0.0, 0.0];
    let p2 = [1.0f32, 0.0, 0.0, 1.0, -3.0, 9.0];
    let fused = bench("pipeline3_1024 (one fused artifact)", || {
        std::hint::black_box(
            exec.run_f32("pipeline3_1024", &[&xs, &ys, &p0, &p1, &p2]).unwrap(),
        );
    });
    let separate = bench("3 × affine1024 (unfused)", || {
        let o1 = exec.run_f32("affine1024", &[&xs, &ys, &p0]).unwrap();
        let o2 = exec.run_f32("affine1024", &[&o1[0], &o1[1], &p1]).unwrap();
        std::hint::black_box(exec.run_f32("affine1024", &[&o2[0], &o2[1], &p2]).unwrap());
    });
    println!(
        "  → fusion speedup: {:.2}x",
        separate.mean.as_secs_f64() / fused.mean.as_secs_f64()
    );

    section("matmul8 (the §5.3 rotation building block)");
    let a: Vec<f32> = (0..64).map(|i| i as f32 * 0.1).collect();
    let b: Vec<f32> = (0..64).map(|i| 6.4 - i as f32 * 0.1).collect();
    bench("matmul8", || {
        std::hint::black_box(
            exec.run_f32_shaped("matmul8", &[(&a, &[8, 8]), (&b, &[8, 8])]).unwrap(),
        );
    });
}
