//! Regenerate every table and figure of the paper's evaluation in one
//! run, and write the CSVs to `reports/`.
//!
//! ```sh
//! cargo run --release --example perf_report
//! ```

use morpho::perf::{
    figure, render_figure, render_table, table1_listing, table2_listing, table3, table4, table5,
    to_csv,
};

fn main() -> anyhow::Result<()> {
    println!("{}\n", table1_listing());
    println!("{}\n", table2_listing());
    println!(
        "{}",
        render_table("Table 3 — vector-vector (translation) on the Intel baselines", &[table3()])
    );
    println!(
        "{}",
        render_table("Table 4 — vector-scalar (scaling) on the Intel baselines", &[table4()])
    );
    println!("{}", render_table("Table 5 — comparisons between algorithms and systems", &table5()));

    for num in 9..=16 {
        let (title, rows, per_elem) = figure(num);
        println!("{}", render_figure(&title, &rows, per_elem));
    }

    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/table3.csv", to_csv(&[table3()]))?;
    std::fs::write("reports/table4.csv", to_csv(&[table4()]))?;
    std::fs::write("reports/table5.csv", to_csv(&table5()))?;
    for num in 9..=16 {
        let (_, rows, _) = figure(num);
        std::fs::write(format!("reports/figure{num}.csv"), to_csv(&[rows]))?;
    }
    println!("CSV reports written to reports/");
    Ok(())
}
