//! Capacity-measurement demo: run one or more named loadgen scenarios
//! against the coordinator's sharded M1-simulator backend and write the
//! combined `BENCH_coordinator.json` capacity report.
//!
//! ```sh
//! cargo run --release --example loadtest                 # smoke + burst
//! cargo run --release --example loadtest steady ramp     # pick scenarios
//! cargo run --release --example loadtest all             # every scenario
//! ```
//!
//! Unlike `repro loadtest <scenario>` (one scenario → one report), this
//! example chains several scenarios into a single artifact — the shape CI
//! and cross-PR trajectory tooling consume — and demonstrates overriding
//! scenario knobs programmatically.

use morpho::loadgen::{self, scenario};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scenarios: Vec<scenario::Scenario> = if args.is_empty() {
        ["smoke", "burst"]
            .iter()
            .map(|n| scenario::by_name(n).expect("built-in scenario"))
            .collect()
    } else if args.len() == 1 && args[0] == "all" {
        scenario::all()
    } else {
        args.iter()
            .map(|n| {
                scenario::by_name(n).unwrap_or_else(|| {
                    eprintln!("unknown scenario `{n}` — known:");
                    for s in scenario::all() {
                        eprintln!("  {:<8} {}", s.name, s.summary);
                    }
                    std::process::exit(2)
                })
            })
            .collect()
    };

    let mut reports = Vec::new();
    for sc in &scenarios {
        println!("── {} ── {} [{}]", sc.name, sc.summary, sc.profile.label());
        let report = loadgen::run_scenario(sc)?;
        println!("{}\n", report.render());
        reports.push(report);
    }

    // A scenario run twice with the same seed offers identical request
    // streams — demonstrate the determinism knob by rerunning the first
    // scenario briefly with a different seed.
    if let Some(first) = scenarios.first() {
        let mut variant = first.clone();
        variant.seed ^= 0xD1CE;
        variant.duration = variant.duration.min(std::time::Duration::from_secs(1));
        println!("── {} (reseeded {:#x}) ──", variant.name, variant.seed);
        let report = loadgen::run_scenario(&variant)?;
        println!("{}\n", report.render());
    }

    let path = loadgen::report::default_path();
    loadgen::report::write_reports(&reports, &path)?;
    println!("wrote {} scenario reports to {path}", reports.len());
    Ok(())
}
