//! Long-lived serving demo: an open-loop synthetic client drives the
//! coordinator at a configurable arrival rate with a mixed transform
//! workload; reports sustained throughput, latency percentiles, batching
//! efficiency, and backpressure behaviour.
//!
//! ```sh
//! cargo run --release --example serve [seconds] [requests_per_sec] [backend]
//! # backend: xla | native | m1sim   (default xla)
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use morpho::coordinator::{BackendChoice, BatcherConfig, Coordinator, CoordinatorConfig};
use morpho::graphics::Transform;
use morpho::testkit::Rng;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let seconds: u64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(5);
    let rate: u64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let backend = match args.next().as_deref() {
        Some("native") => BackendChoice::Native,
        Some("m1sim") => BackendChoice::M1Sim,
        _ => BackendChoice::Xla,
    };

    println!("serving {seconds}s of open-loop load at {rate} req/s on {backend:?}…");
    let c = Arc::new(Coordinator::start(CoordinatorConfig {
        backend,
        workers: 2,
        queue_capacity: 4096,
        batcher: BatcherConfig { max_wait: Duration::from_micros(500), ..Default::default() },
        ..Default::default()
    })?);

    let rejected = Arc::new(AtomicU64::new(0));
    let completed = Arc::new(AtomicU64::new(0));

    // Client thread: Poisson-ish arrivals, mixed request sizes and a
    // small transform vocabulary (so batching has something to merge).
    let client = {
        let c = c.clone();
        let completed = completed.clone();
        let rejected = rejected.clone();
        std::thread::spawn(move || {
            let mut rng = Rng::new(7);
            let deadline = Instant::now() + Duration::from_secs(seconds);
            let interval = Duration::from_nanos(1_000_000_000 / rate.max(1));
            let mut next = Instant::now();
            let mut waiters = Vec::new();
            while Instant::now() < deadline {
                next += interval;
                let n = [8usize, 64, 256, 1024][rng.below(4) as usize];
                let xs: Vec<f32> = (0..n).map(|_| rng.f32_range(-100.0, 100.0)).collect();
                let ys: Vec<f32> = (0..n).map(|_| rng.f32_range(-100.0, 100.0)).collect();
                let transforms = match rng.below(3) {
                    0 => vec![Transform::Translate { tx: 5.0, ty: -2.0 }],
                    1 => vec![Transform::Scale { sx: 1.5, sy: 1.5 }],
                    _ => vec![
                        Transform::Rotate { theta: 0.3 },
                        Transform::Translate { tx: 1.0, ty: 1.0 },
                    ],
                };
                match c.submit(xs, ys, transforms) {
                    Ok(rx) => waiters.push(rx),
                    Err(_) => break,
                }
                // Reap completions opportunistically.
                waiters.retain(|rx| match rx.try_recv() {
                    Ok(Ok(_)) => {
                        completed.fetch_add(1, Ordering::Relaxed);
                        false
                    }
                    Ok(Err(_)) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                        false
                    }
                    Err(_) => true,
                });
                if let Some(sleep) = next.checked_duration_since(Instant::now()) {
                    std::thread::sleep(sleep);
                }
            }
            // Drain the stragglers.
            for rx in waiters {
                match rx.recv() {
                    Ok(Ok(_)) => {
                        completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(Err(_)) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {}
                }
            }
        })
    };

    client.join().unwrap();
    let m = c.metrics();
    println!("\n{}", m.render());
    println!(
        "completed {} requests ({} rejected); sustained ≈{:.0} req/s, {:.2} M points/s",
        completed.load(Ordering::Relaxed),
        rejected.load(Ordering::Relaxed),
        completed.load(Ordering::Relaxed) as f64 / seconds as f64,
        m.points as f64 / seconds as f64 / 1e6,
    );
    Ok(())
}
