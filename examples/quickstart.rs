//! Quickstart: transform a unit square through the full stack — the
//! coordinator batches the request and executes it on the AOT-compiled
//! JAX/Pallas artifact via PJRT (no Python at runtime).
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use morpho::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use morpho::graphics::Transform;

fn main() -> anyhow::Result<()> {
    // A unit square.
    let xs = vec![0.0f32, 1.0, 1.0, 0.0];
    let ys = vec![0.0f32, 0.0, 1.0, 1.0];
    println!("square:      {:?}", xs.iter().zip(&ys).collect::<Vec<_>>());

    // Scale ×2, rotate 45°, translate by (3, 1) — §4's three transforms
    // composed.
    let transforms = vec![
        Transform::Scale { sx: 2.0, sy: 2.0 },
        Transform::Rotate { theta: std::f32::consts::FRAC_PI_4 },
        Transform::Translate { tx: 3.0, ty: 1.0 },
    ];

    let coordinator = Coordinator::start(CoordinatorConfig {
        backend: BackendChoice::Xla,
        workers: 1,
        ..Default::default()
    })?;

    let resp = coordinator.transform_blocking(xs, ys, transforms)?;
    println!(
        "transformed: {:?}",
        resp.xs.iter().zip(&resp.ys).map(|(x, y)| (format!("{x:.3}"), format!("{y:.3}"))).collect::<Vec<_>>()
    );
    println!(
        "served by {} backend in {:?} (queued {:?})",
        resp.timing.backend.name(),
        resp.timing.execute,
        resp.timing.queued
    );

    // Same request on the MorphoSys M1 simulator — the paper's machine.
    let m1 = Coordinator::start(CoordinatorConfig {
        backend: BackendChoice::M1Sim,
        workers: 1,
        ..Default::default()
    })?;
    let resp = m1.transform_blocking(
        vec![0.0, 8.0, 8.0, 0.0],
        vec![0.0, 0.0, 8.0, 8.0],
        vec![Transform::Translate { tx: 3.0, ty: 1.0 }],
    )?;
    println!(
        "\nM1 simulator: translated square {:?} in {} simulated cycles ({} ns at 100 MHz)",
        resp.xs.iter().zip(&resp.ys).collect::<Vec<_>>(),
        resp.timing.simulated_cycles.unwrap(),
        resp.timing.simulated_cycles.unwrap() * 10
    );

    coordinator.shutdown();
    m1.shutdown();
    Ok(())
}
