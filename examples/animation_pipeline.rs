//! END-TO-END DRIVER — the full system on a real (synthetic) workload.
//!
//! The paper motivates its mappings with graphics animation (Figure 4:
//! "image tracking while applying different 2D transformations"). This
//! example builds that workload at scale and pushes it through every
//! layer of this crate:
//!
//! 1. generate a synthetic 2-D scene (10 000 polygons, ≈65 000 vertices);
//! 2. animate `FRAMES` frames of composite scale∘rotate∘translate
//!    transforms, each frame submitted to the **coordinator** as a batch
//!    of per-polygon requests (dynamic batching merges them);
//! 3. execute on the **XLA backend** — the AOT-compiled JAX/Pallas
//!    artifacts via PJRT, Python nowhere in the loop;
//! 4. report throughput and latency percentiles;
//! 5. replay the same frame workload on the **M1 simulator** backend and
//!    the **Intel baseline models**, reporting the paper-style speedup
//!    table on this real workload.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example animation_pipeline [frames]
//! ```

use std::time::Instant;

use morpho::baselines::{routines as x86, Cpu};
use morpho::coordinator::{BackendChoice, BatcherConfig, Coordinator, CoordinatorConfig};
use morpho::graphics::{Scene, Transform};
use morpho::morphosys::timing::M1_CLOCK_HZ;

fn frame_transforms(frame: usize) -> Vec<Transform> {
    let t = frame as f32 / 30.0;
    vec![
        Transform::Scale { sx: 1.0 + 0.3 * (t * 0.7).sin(), sy: 1.0 + 0.3 * (t * 0.9).cos() },
        Transform::Rotate { theta: 0.2 * t },
        Transform::Translate { tx: 10.0 * t.sin(), ty: 6.0 * t.cos() },
    ]
}

fn run_backend(
    label: &str,
    backend: BackendChoice,
    scene: &Scene,
    frames: usize,
) -> anyhow::Result<(f64, u64)> {
    let c = Coordinator::start(CoordinatorConfig {
        backend,
        workers: 2,
        batcher: BatcherConfig {
            max_wait: std::time::Duration::from_micros(300),
            ..Default::default()
        },
        ..Default::default()
    })?;
    let (xs, ys) = scene.coords();
    let total_points = scene.len() * frames;

    let t0 = Instant::now();
    for frame in 0..frames {
        let transforms = frame_transforms(frame);
        // One request per polygon — the realistic request granularity a
        // scene graph produces; the batcher re-merges them into tiles.
        let receivers: Vec<_> = scene
            .polygons
            .iter()
            .map(|poly| {
                let pxs: Vec<f32> = poly.iter().map(|&i| xs[i as usize]).collect();
                let pys: Vec<f32> = poly.iter().map(|&i| ys[i as usize]).collect();
                c.submit(pxs, pys, transforms.clone())
            })
            .collect::<Result<_, _>>()?;
        for rx in receivers {
            rx.recv()?.expect("animation requests carry no TTL, so none are shed");
        }
    }
    let elapsed = t0.elapsed();
    let m = c.metrics();
    let pts_per_sec = total_points as f64 / elapsed.as_secs_f64();
    println!(
        "{label:<8} {frames} frames × {} pts: {:.2}s  → {:>8.2} M points/s, {:>6.1} frames/s",
        scene.len(),
        elapsed.as_secs_f64(),
        pts_per_sec / 1e6,
        frames as f64 / elapsed.as_secs_f64(),
    );
    println!(
        "         requests={} jobs={} mean_batch={:.0}pts  exec p50={}µs p99={}µs  queue p99={}µs",
        m.requests,
        m.jobs,
        m.mean_batch_points(),
        m.execute_p50_us,
        m.execute_p99_us,
        m.queue_wait_p99_us
    );
    c.shutdown();
    Ok((pts_per_sec, m.simulated_cycles))
}

fn main() -> anyhow::Result<()> {
    let frames: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let scene = Scene::synthetic(10_000, 100.0, 42);
    println!(
        "scene: {} polygons, {} vertices; animating {} frames of composite\n\
         scale∘rotate∘translate transforms\n",
        scene.polygons.len(),
        scene.len(),
        frames
    );

    // The serving path: XLA artifacts via PJRT.
    let (xla_pps, _) = run_backend("XLA", BackendChoice::Xla, &scene, frames)?;
    // Native reference for context.
    let (native_pps, _) = run_backend("native", BackendChoice::Native, &scene, frames)?;

    // The paper's machine: M1 simulator (fewer frames — it's a
    // cycle-accurate simulator, not a production backend).
    let m1_frames = frames.min(10);
    let (_, sim_cycles) = run_backend("M1(sim)", BackendChoice::M1Sim, &scene, m1_frames)?;
    let m1_points = (scene.len() * m1_frames) as f64;
    let m1_cycles_per_point = sim_cycles as f64 / m1_points;
    let m1_us_per_frame = sim_cycles as f64 / m1_frames as f64 / (M1_CLOCK_HZ as f64 / 1e6);
    println!(
        "\nsimulated M1 hardware: {:.2} cycles/point → a real 100 MHz M1 would do {:.1} µs/frame\n\
         ({:.1} M points/s — the paper's machine would sustain {:.0} fps on this scene)",
        m1_cycles_per_point,
        m1_us_per_frame,
        (M1_CLOCK_HZ as f64 / m1_cycles_per_point) / 1e6,
        1e6 / m1_us_per_frame,
    );

    // Paper-style comparison on this workload's per-frame op mix:
    // translation of all points (vec-vec) per frame on each baseline.
    println!("\npaper-style speedup on this workload (per-frame translation of all vertices):");
    let n_tiles = scene.len().div_ceil(64);
    let m1_frame_cycles = n_tiles as u64 * 96; // calibrated Table 5 cell
    println!("  M1 (64-el tiles × {n_tiles}): {m1_frame_cycles} cycles/frame");
    let u: Vec<i16> = (0..64).collect();
    let v = vec![1i16; 64];
    for cpu in [Cpu::I486, Cpu::I386, Cpu::Pentium] {
        let per_tile = x86::run_translation(cpu, &u, &v).1.cycles;
        let frame_cycles = per_tile * n_tiles as u64;
        println!(
            "  {:<8} {:>12} cycles/frame → M1 speedup {:>6.2}x (paper 64-el: {})",
            cpu.name(),
            frame_cycles,
            frame_cycles as f64 / m1_frame_cycles as f64,
            match cpu {
                Cpu::I486 => "8.01x",
                Cpu::I386 => "17.94x",
                Cpu::Pentium => "n/a",
            }
        );
    }

    println!(
        "\nsummary: XLA path {:.2} M pts/s vs native {:.2} M pts/s on this host; \
         all layers (Pallas kernel → JAX pipeline → HLO artifact → PJRT → \
         coordinator) compose.",
        xla_pps / 1e6,
        native_pps / 1e6
    );
    Ok(())
}
