//! mULATE-style traces of the paper's Table 1 and Table 2 routines: emit
//! the TinyRISC listings from the mapping compiler, execute them on the
//! cycle-accurate M1 simulator with tracing, and verify both the result
//! and the paper's cycle counts.
//!
//! ```sh
//! cargo run --release --example mulate_trace
//! ```

use morpho::mapping::{runner::run_routine_on, VecScalarMapping, VecVecMapping};
use morpho::morphosys::{AluOp, M1System};
use morpho::perf::{table1_listing, table2_listing};

fn main() {
    println!("{}\n", table1_listing());

    // Execute the Table 1 routine with tracing: U = 0..64, V = 100..164.
    let routine = VecVecMapping { n: 64, op: AluOp::Add }.compile();
    let u: Vec<i16> = (0..64).collect();
    let v: Vec<i16> = (100..164).collect();
    let mut sys = M1System::new().with_trace();
    let out = run_routine_on(&mut sys, &routine, &u, Some(&v));
    println!("mULATE trace (translation, 64 elements):");
    println!("{}", sys.take_trace().unwrap().render());
    println!(
        "cycles = {} (paper: 96)   result[0..8] = {:?}\n",
        out.report.cycles,
        &out.result[..8]
    );
    assert_eq!(out.report.cycles, 96);

    println!("{}\n", table2_listing());
    let routine = VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile();
    let mut sys = M1System::new().with_trace();
    let out = run_routine_on(&mut sys, &routine, &u, None);
    println!("mULATE trace (scaling ×5, 64 elements):");
    println!("{}", sys.take_trace().unwrap().render());
    println!(
        "cycles = {} (paper: 55)   result[0..8] = {:?}",
        out.report.cycles,
        &out.result[..8]
    );
    assert_eq!(out.report.cycles, 55);
}
