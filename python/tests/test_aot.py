"""AOT path: artifacts lower, serialize to HLO text, and the text looks
like something the rust loader (HloModuleProto::from_text_file) accepts."""

import os

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model


def test_to_hlo_text_roundtrips_through_xla_parser(tmp_path):
    lowered = jax.jit(model.matmul).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ROOT" in text
    # Must be the tuple-returning form the rust side unwraps.
    assert "(f32[8,8]" in text


def test_build_subset_writes_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build(out, names={"translate64", "matmul8"})
    files = sorted(os.listdir(out))
    assert "translate64.hlo.txt" in files
    assert "matmul8.hlo.txt" in files
    assert "manifest.txt" in files
    text = open(os.path.join(out, "translate64.hlo.txt")).read()
    assert text.startswith("HloModule")


def test_artifact_functions_execute_correctly():
    # Run each artifact function jitted (the exact computation the HLO
    # captures) against its expected output.
    u = jnp.arange(64, dtype=jnp.float32)
    v = 2.0 * u
    (out,) = jax.jit(model.translate_vectors)(u, v)
    assert_allclose(np.asarray(out), np.asarray(3.0 * u))

    params = jnp.asarray([0.0, -1.0, 1.0, 0.0, 5.0, -5.0], dtype=jnp.float32)
    ox, oy = jax.jit(model.affine_tile)(u, v, params)
    assert_allclose(np.asarray(ox), np.asarray(-v + 5.0))
    assert_allclose(np.asarray(oy), np.asarray(u - 5.0))


def test_manifest_covers_all_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build(out, names={"scale64"})
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert "scale64" in manifest
    assert "shapes=64;1" in manifest
