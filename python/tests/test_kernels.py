"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes and dtypes with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels import transform as k

SIZES = [8, 16, 32, 64, 128, 1024]
DTYPES = [jnp.float32, jnp.int32]


def arrays(draw, n, dtype, lo=-1000, hi=1000):
    elems = draw(
        st.lists(st.integers(min_value=lo, max_value=hi), min_size=n, max_size=n)
    )
    return jnp.asarray(np.array(elems), dtype=dtype)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_translate_matches_ref(data):
    n = data.draw(st.sampled_from(SIZES))
    dtype = data.draw(st.sampled_from(DTYPES))
    u = arrays(data.draw, n, dtype)
    v = arrays(data.draw, n, dtype)
    assert_allclose(np.asarray(k.translate(u, v)), np.asarray(ref.translate(u, v)))


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_scale_matches_ref(data):
    n = data.draw(st.sampled_from(SIZES))
    dtype = data.draw(st.sampled_from(DTYPES))
    u = arrays(data.draw, n, dtype)
    c = arrays(data.draw, 1, dtype, lo=-50, hi=50)
    assert_allclose(np.asarray(k.scale(u, c)), np.asarray(ref.scale(u, c)))


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_affine_matches_ref(data):
    n = data.draw(st.sampled_from(SIZES))
    xs = arrays(data.draw, n, jnp.float32)
    ys = arrays(data.draw, n, jnp.float32)
    p = data.draw(
        st.lists(
            st.floats(min_value=-10, max_value=10, allow_nan=False),
            min_size=6,
            max_size=6,
        )
    )
    params = jnp.asarray(np.array(p, dtype=np.float32))
    ox, oy = k.affine_points(xs, ys, params)
    rx, ry = ref.affine_points(xs, ys, params)
    assert_allclose(np.asarray(ox), np.asarray(rx), rtol=1e-5, atol=1e-3)
    assert_allclose(np.asarray(oy), np.asarray(ry), rtol=1e-5, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_matmul_matches_ref(data):
    d = data.draw(st.sampled_from([2, 4, 8, 16]))
    a = arrays(data.draw, d * d, jnp.float32, lo=-100, hi=100).reshape(d, d)
    b = arrays(data.draw, d * d, jnp.float32, lo=-100, hi=100).reshape(d, d)
    assert_allclose(
        np.asarray(k.matmul8(a, b)), np.asarray(ref.matmul8(a, b)), rtol=1e-5
    )


def test_column_major_layout_matches_paper_figure7():
    # The kernel's internal layout must place element i at
    # (i mod 8, i div 8) — the paper's Figure 7.
    u = jnp.arange(64, dtype=jnp.float32)
    g = k._to_grid(u)
    assert g.shape == (8, 8)
    assert g[1, 1] == 9  # U9 at row 1, col 1 per Figure 7
    assert g[0, 7] == 56
    assert np.array_equal(np.asarray(k._from_grid(g)), np.asarray(u))


def test_ragged_sizes_rejected():
    u = jnp.arange(12, dtype=jnp.float32)
    with pytest.raises(AssertionError):
        k.translate(u, u)


def test_identity_affine_is_exact():
    xs = jnp.arange(64, dtype=jnp.float32)
    ys = -xs
    params = jnp.asarray([1.0, 0.0, 0.0, 1.0, 0.0, 0.0], dtype=jnp.float32)
    ox, oy = k.affine_points(xs, ys, params)
    assert np.array_equal(np.asarray(ox), np.asarray(xs))
    assert np.array_equal(np.asarray(oy), np.asarray(ys))


def test_translate_paper_example():
    # 64-element translation, the Table 1 workload.
    u = jnp.arange(64, dtype=jnp.float32)
    v = jnp.full((64,), 5.0, dtype=jnp.float32)
    out = k.translate(u, v)
    assert_allclose(np.asarray(out), np.arange(64) + 5.0)


def test_scale_paper_example():
    # ×5 scaling — the 00009005 context word.
    u = jnp.arange(64, dtype=jnp.float32)
    out = k.scale(u, jnp.asarray([5.0], dtype=jnp.float32))
    assert_allclose(np.asarray(out), np.arange(64) * 5.0)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_affine3d_matches_ref(data):
    n = data.draw(st.sampled_from([8, 64, 1024]))
    xs = arrays(data.draw, n, jnp.float32, lo=-100, hi=100)
    ys = arrays(data.draw, n, jnp.float32, lo=-100, hi=100)
    zs = arrays(data.draw, n, jnp.float32, lo=-100, hi=100)
    p = data.draw(
        st.lists(
            st.floats(min_value=-5, max_value=5, allow_nan=False),
            min_size=12,
            max_size=12,
        )
    )
    params = jnp.asarray(np.array(p, dtype=np.float32))
    got = k.affine3d_points(xs, ys, zs, params)
    want = ref.affine3d_points(xs, ys, zs, params)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-3)


def test_affine3d_identity_is_exact():
    n = 64
    xs = jnp.arange(n, dtype=jnp.float32)
    ys = -xs
    zs = 2.0 * xs
    params = jnp.asarray(
        [1, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0], dtype=jnp.float32
    )
    ox, oy, oz = k.affine3d_points(xs, ys, zs, params)
    assert np.array_equal(np.asarray(ox), np.asarray(xs))
    assert np.array_equal(np.asarray(oy), np.asarray(ys))
    assert np.array_equal(np.asarray(oz), np.asarray(zs))
