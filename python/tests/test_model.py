"""L2 correctness: pipeline composition, shapes, and jit-lowerability."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model

F32 = jnp.float32


def p6(vals):
    return jnp.asarray(np.array(vals, dtype=np.float32))


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_pipeline3_equals_composed_affine(data):
    f = st.floats(min_value=-3, max_value=3, allow_nan=False)
    ps = [p6(data.draw(st.lists(f, min_size=6, max_size=6))) for _ in range(3)]
    xs = jnp.linspace(-10, 10, 64, dtype=F32)
    ys = jnp.linspace(5, -5, 64, dtype=F32)
    px, py = model.pipeline3(xs, ys, *ps)
    fused = model.compose_affine(model.compose_affine(ps[0], ps[1]), ps[2])
    fx, fy = model.affine_tile(xs, ys, fused)
    assert_allclose(np.asarray(px), np.asarray(fx), rtol=1e-3, atol=1e-2)
    assert_allclose(np.asarray(py), np.asarray(fy), rtol=1e-3, atol=1e-2)


def test_compose_affine_identity():
    ident = p6([1, 0, 0, 1, 0, 0])
    other = p6([2, 1, -1, 0.5, 3, -4])
    assert_allclose(
        np.asarray(model.compose_affine(ident, other)), np.asarray(other)
    )
    assert_allclose(
        np.asarray(model.compose_affine(other, ident)), np.asarray(other)
    )


def test_translate_then_scale_order():
    # compose_affine(p0, p1) applies p0 FIRST.
    translate = p6([1, 0, 0, 1, 10, 0])
    scale = p6([2, 0, 0, 2, 0, 0])
    fused = model.compose_affine(translate, scale)
    xs = jnp.asarray([1.0], dtype=F32) * jnp.ones(8, F32)
    ys = jnp.zeros(8, F32)
    ox, _ = model.affine_tile(xs, ys, fused)
    # (1 + 10) * 2 = 22.
    assert_allclose(np.asarray(ox), np.full(8, 22.0))


def test_all_model_fns_lower_to_stablehlo():
    vec = jax.ShapeDtypeStruct((64,), F32)
    par = jax.ShapeDtypeStruct((6,), F32)
    sca = jax.ShapeDtypeStruct((1,), F32)
    m8 = jax.ShapeDtypeStruct((8, 8), F32)
    cases = [
        (model.translate_vectors, (vec, vec)),
        (model.scale_vector, (vec, sca)),
        (model.affine_tile, (vec, vec, par)),
        (model.pipeline3, (vec, vec, par, par, par)),
        (model.matmul, (m8, m8)),
    ]
    for fn, args in cases:
        lowered = jax.jit(fn).lower(*args)
        ir = str(lowered.compiler_ir("stablehlo"))
        assert "stablehlo" in ir or "func.func" in ir


def test_outputs_are_tuples():
    xs = jnp.zeros(64, F32)
    out = model.translate_vectors(xs, xs)
    assert isinstance(out, tuple) and len(out) == 1
    out = model.affine_tile(xs, xs, p6([1, 0, 0, 1, 0, 0]))
    assert isinstance(out, tuple) and len(out) == 2
