"""AOT bridge: lower the L2 functions to HLO **text** artifacts for the
rust PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def vec(n):
    return jax.ShapeDtypeStruct((n,), F32)


def mat(d):
    return jax.ShapeDtypeStruct((d, d), F32)


def params():
    return jax.ShapeDtypeStruct((6,), F32)


def params3d():
    return jax.ShapeDtypeStruct((12,), F32)


def scalar():
    return jax.ShapeDtypeStruct((1,), F32)


# name -> (function, example args). Tile sizes: 64 is the M1's natural
# tile; 1024/4096 amortize PJRT call overhead for bulk scenes.
ARTIFACTS = {
    "translate64": (model.translate_vectors, (vec(64), vec(64))),
    "translate1024": (model.translate_vectors, (vec(1024), vec(1024))),
    "scale64": (model.scale_vector, (vec(64), scalar())),
    "scale1024": (model.scale_vector, (vec(1024), scalar())),
    "affine64": (model.affine_tile, (vec(64), vec(64), params())),
    "affine1024": (model.affine_tile, (vec(1024), vec(1024), params())),
    "affine4096": (model.affine_tile, (vec(4096), vec(4096), params())),
    "pipeline3_1024": (
        model.pipeline3,
        (vec(1024), vec(1024), params(), params(), params()),
    ),
    "matmul8": (model.matmul, (mat(8), mat(8))),
    "affine3d_1024": (
        model.affine3d_tile,
        (vec(1024), vec(1024), vec(1024), params3d()),
    ),
}


def build(out_dir: str, names=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, (fn, args) in sorted(ARTIFACTS.items()):
        if names and name not in names:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ";".join(
            "x".join(map(str, a.shape)) if a.shape else "scalar" for a in args
        )
        manifest.append(f"{name} inputs={len(args)} shapes={shapes}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("names", nargs="*", help="subset of artifacts to build")
    args = ap.parse_args()
    build(args.out_dir, set(args.names) or None)


if __name__ == "__main__":
    main()
