"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in :mod:`transform` must match its oracle here to float
tolerance across the shape/dtype sweep in ``python/tests``.
"""

import jax.numpy as jnp


def translate(u, v):
    """Vector-vector addition — the paper's §5.1 translation mapping."""
    return u + v


def scale(u, c):
    """Vector-scalar multiplication — the paper's §5.2 scaling mapping.

    ``c`` is a length-1 array (the runtime analogue of the context-word
    immediate).
    """
    return u * c[0]


def affine_points(xs, ys, params):
    """Affine point transform ``q = M p + t``.

    ``params = [a, b, c, d, tx, ty]`` row-major: ``x' = a·x + b·y + tx``,
    ``y' = c·x + d·y + ty`` — the composite transformation the paper's
    §5.3 accelerates via matrix algebra.
    """
    a, b, c, d, tx, ty = (params[i] for i in range(6))
    return xs * a + ys * b + tx, xs * c + ys * d + ty


def matmul8(a, b):
    """Dense matrix product — the §5.3 rotation building block."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def affine3d_points(xs, ys, zs, params):
    """3-D affine oracle: ``params = [m00..m22, tx, ty, tz]``."""
    m = [params[i] for i in range(9)]
    tx, ty, tz = params[9], params[10], params[11]
    return (
        xs * m[0] + ys * m[1] + zs * m[2] + tx,
        xs * m[3] + ys * m[4] + zs * m[5] + ty,
        xs * m[6] + ys * m[7] + zs * m[8] + tz,
    )
