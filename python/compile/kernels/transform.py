"""Pallas kernels for the paper's linear-algebraic mappings.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the M1 executes a
64-element vector op as eight *column broadcasts*, each consuming eight
consecutive frame-buffer elements. Here that schedule becomes a Pallas
grid: vectors are laid out ``(8, n/8)`` column-major (element ``i`` at
``(i mod 8, i div 8)``, exactly the paper's Figure 7/8 layout) and each
grid step processes one ``(8, 1)`` block — the BlockSpec expresses the
HBM→VMEM schedule the M1 expressed with frame-buffer addressing, and the
double-buffering of the M1's two frame-buffer sets is what Pallas's
pipelined grid does automatically.

All kernels use ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT client cannot execute; interpret mode lowers to
plain HLO so the artifacts run anywhere (numerics identical).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 8  # the RC array edge: one column broadcast = 8 elements


def _to_grid(u):
    """Flat (n,) → (8, n/8) in the paper's column-major layout."""
    n = u.shape[-1]
    assert n % LANES == 0, f"vector length {n} must be a multiple of {LANES}"
    return u.reshape(n // LANES, LANES).T


def _from_grid(g):
    return g.T.reshape(-1)


# --- §5.1: vector-vector (translation) --------------------------------------


def _translate_kernel(u_ref, v_ref, o_ref):
    # One M1 column broadcast: OUT = A + B (context word 0000F400).
    o_ref[...] = u_ref[...] + v_ref[...]


def translate(u, v):
    """Element-wise ``u + v`` with the M1 column-broadcast schedule."""
    ug, vg = _to_grid(u), _to_grid(v)
    cols = ug.shape[1]
    out = pl.pallas_call(
        _translate_kernel,
        grid=(cols,),
        in_specs=[
            pl.BlockSpec((LANES, 1), lambda c: (0, c)),
            pl.BlockSpec((LANES, 1), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((LANES, 1), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct(ug.shape, ug.dtype),
        interpret=True,
    )(ug, vg)
    return _from_grid(out)


# --- §5.2: vector-scalar (scaling) -------------------------------------------


def _scale_kernel(c_ref, u_ref, o_ref):
    # OUT = c × A (context word 00009005 when c = 5); the scalar rides
    # along like the context-word immediate.
    o_ref[...] = u_ref[...] * c_ref[0]


def scale(u, c):
    """Element-wise ``u * c[0]``; ``c`` is a length-1 array."""
    ug = _to_grid(u)
    cols = ug.shape[1]
    out = pl.pallas_call(
        _scale_kernel,
        grid=(cols,),
        in_specs=[
            pl.BlockSpec((1,), lambda c: (0,)),
            pl.BlockSpec((LANES, 1), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((LANES, 1), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct(ug.shape, ug.dtype),
        interpret=True,
    )(c, ug)
    return _from_grid(out)


# --- composite affine point transform ----------------------------------------


def _affine_kernel(p_ref, x_ref, y_ref, ox_ref, oy_ref):
    a, b, c, d, tx, ty = (p_ref[i] for i in range(6))
    x, y = x_ref[...], y_ref[...]
    ox_ref[...] = x * a + y * b + tx
    oy_ref[...] = x * c + y * d + ty


def affine_points(xs, ys, params):
    """``q = M·p + t`` over parallel coordinate arrays.

    ``params = [a, b, c, d, tx, ty]``. X coordinates stream through one
    operand bank, Y through the other — the M1's dual-bank frame buffer.
    """
    xg, yg = _to_grid(xs), _to_grid(ys)
    cols = xg.shape[1]
    ox, oy = pl.pallas_call(
        _affine_kernel,
        grid=(cols,),
        in_specs=[
            pl.BlockSpec((6,), lambda c: (0,)),
            pl.BlockSpec((LANES, 1), lambda c: (0, c)),
            pl.BlockSpec((LANES, 1), lambda c: (0, c)),
        ],
        out_specs=[
            pl.BlockSpec((LANES, 1), lambda c: (0, c)),
            pl.BlockSpec((LANES, 1), lambda c: (0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xg.shape, xg.dtype),
            jax.ShapeDtypeStruct(yg.shape, yg.dtype),
        ],
        interpret=True,
    )(params, xg, yg)
    return _from_grid(ox), _from_grid(oy)


# --- 3-D composite affine point transform -------------------------------------


def _affine3d_kernel(p_ref, x_ref, y_ref, z_ref, ox_ref, oy_ref, oz_ref):
    m = [p_ref[i] for i in range(9)]
    tx, ty, tz = p_ref[9], p_ref[10], p_ref[11]
    x, y, z = x_ref[...], y_ref[...], z_ref[...]
    ox_ref[...] = x * m[0] + y * m[1] + z * m[2] + tx
    oy_ref[...] = x * m[3] + y * m[4] + z * m[5] + ty
    oz_ref[...] = x * m[6] + y * m[7] + z * m[8] + tz


def affine3d_points(xs, ys, zs, params):
    """``q = M·p + t`` over parallel 3-D coordinate arrays.

    ``params = [m00..m22 row-major, tx, ty, tz]`` — the reference [8]
    ("2D and 3D Computer Graphics Algorithms under MorphoSys") extension.
    The third coordinate stream mirrors the M1 mapping's use of frame
    buffer set 1 bank A.
    """
    xg, yg, zg = _to_grid(xs), _to_grid(ys), _to_grid(zs)
    cols = xg.shape[1]
    spec = pl.BlockSpec((LANES, 1), lambda c: (0, c))
    ox, oy, oz = pl.pallas_call(
        _affine3d_kernel,
        grid=(cols,),
        in_specs=[pl.BlockSpec((12,), lambda c: (0,)), spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct(xg.shape, xg.dtype)] * 3,
        interpret=True,
    )(params, xg, yg, zg)
    return _from_grid(ox), _from_grid(oy), _from_grid(oz)


# --- §5.3: dense matmul (rotation / composite) --------------------------------


def _matmul_kernel(a_ref, b_ref, o_ref):
    # The CMUL-accumulate of §5.3, targeted at the MXU instead of the
    # RC-array ALU chain: one dot over the whole (small) tile.
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul8(a, b):
    """Dense square matmul (8×8 in the paper; any dim ≤ 128 here)."""
    assert a.shape == b.shape and a.shape[0] == a.shape[1]
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=True,
    )(a, b)
