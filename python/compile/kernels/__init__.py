"""L1 — Pallas kernels for the paper's compute hot-spots.

The M1's column-broadcast SIMD execution is re-thought for TPU-class
hardware here (see DESIGN.md §Hardware-Adaptation): the frame-buffer
column layout becomes a BlockSpec grid, the context-word immediate becomes
a scalar operand, and the §5.3 CMUL-accumulate matmul becomes an
MXU-targeted `jnp.dot`. All kernels are lowered with ``interpret=True``
(CPU PJRT cannot execute Mosaic custom-calls).
"""

from .transform import (  # noqa: F401
    affine3d_points,
    affine_points,
    matmul8,
    scale,
    translate,
)
from . import ref  # noqa: F401
