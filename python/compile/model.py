"""L2 — the JAX compute graph: batched transform pipelines over the L1
Pallas kernels.

These are the functions `aot.py` lowers to the HLO artifacts the rust
coordinator executes. Affine parameters are *runtime* inputs (one artifact
serves every transform), exactly as the M1 reused one context word across
data tiles.
"""

import jax.numpy as jnp

from .kernels import transform as k


def translate_vectors(u, v):
    """Artifact ``translate<n>``: the paper's §5.1 routine."""
    return (k.translate(u, v),)


def scale_vector(u, c):
    """Artifact ``scale<n>``: the paper's §5.2 routine (runtime scalar)."""
    return (k.scale(u, c),)


def affine_tile(xs, ys, params):
    """Artifact ``affine<n>``: one affine transform over an n-point tile."""
    ox, oy = k.affine_points(xs, ys, params)
    return (ox, oy)


def pipeline3(xs, ys, p0, p1, p2):
    """Artifact ``pipeline3_<n>``: three chained affine stages (e.g.
    scale → rotate → translate), demonstrating cross-kernel fusion by XLA.
    """
    xs, ys = k.affine_points(xs, ys, p0)
    xs, ys = k.affine_points(xs, ys, p1)
    xs, ys = k.affine_points(xs, ys, p2)
    return (xs, ys)


def affine3d_tile(xs, ys, zs, params):
    """Artifact ``affine3d_<n>``: one 3-D affine transform over an n-point
    tile (params = 12 floats: row-major 3×3 + translation)."""
    ox, oy, oz = k.affine3d_points(xs, ys, zs, params)
    return (ox, oy, oz)


def matmul(a, b):
    """Artifact ``matmul<d>``: the §5.3 rotation/composite matrix product."""
    return (k.matmul8(a, b),)


def compose_affine(p0, p1):
    """Compose two affine parameter vectors: apply p0 first, then p1.

    Pure jnp (no kernel) — used by tests to validate pipeline3 against a
    single fused affine.
    """
    a0, b0, c0, d0, tx0, ty0 = (p0[i] for i in range(6))
    a1, b1, c1, d1, tx1, ty1 = (p1[i] for i in range(6))
    return jnp.stack(
        [
            a1 * a0 + b1 * c0,
            a1 * b0 + b1 * d0,
            c1 * a0 + d1 * c0,
            c1 * b0 + d1 * d0,
            a1 * tx0 + b1 * ty0 + tx1,
            c1 * tx0 + d1 * ty0 + ty1,
        ]
    )
