#!/usr/bin/env python3
"""Shared capacity-report checker for the serving-layer CI jobs.

Every loadgen smoke job ends the same way: run a `repro` verb, assert the
emitted JSON report satisfies the job's invariants, upload the artifact.
This script is the shared "assert" half; the `.github/actions/loadtest-check`
composite action wires it between the run and the upload.

Three modes, each reading one or more report files:

  rows        BENCH_coordinator.json rows (a JSON array of capacity
              reports). Select rows with --scenario/--transport, then
              evaluate every --require expression against each selected
              row with the row's columns bound as variables:

                check_report.py rows R.json --scenario chaos \\
                    --require "failed == 0" --require "shard_crashes > 0"

  ab          Adaptive-batching A/B: the adaptive row's throughput_rps
              must be >= --tolerance x each static-extreme row's:

                check_report.py ab min.json max.json adaptive.json \\
                    --adaptive mixed-adaptive \\
                    --extremes mixed-window-min mixed-window-max

  saturation  BENCH_saturation.json (the `repro sweep` surface): every
              grid cell must be populated — knee_rps > 0, submitted > 0,
              failed == 0 — and the cell count must reach --min-cells.

Multiple report files are merged (rows concatenated) before checking, so
jobs that write one file per run can still be gated as a set. Exits
nonzero with a per-row diagnosis on the first unsatisfied invariant.
"""

import argparse
import json
import sys


def load_rows(paths):
    rows = []
    for path in paths:
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, list):
            raise SystemExit(f"{path}: expected a JSON array of capacity reports")
        if not data:
            raise SystemExit(f"{path}: no scenario rows")
        rows.extend(data)
    return rows


def describe(row):
    return (f"{row.get('scenario', '?')} [{row.get('transport', '?')}"
            f", window={row.get('batch_window', '?')}]")


def check_rows(args):
    rows = load_rows(args.reports)
    if args.scenario:
        rows = [r for r in rows if r.get("scenario") == args.scenario]
    if args.transport:
        rows = [r for r in rows if r.get("transport") == args.transport]
    if not rows:
        raise SystemExit(
            f"no rows match scenario={args.scenario!r} transport={args.transport!r}")

    failures = []
    for row in rows:
        print(f"{describe(row)}: {row.get('completed')} completed, "
              f"{row.get('failed')} failed, {row.get('shed')} shed, "
              f"{row.get('throughput_rps', 0):.0f} req/s, "
              f"p99 {row.get('latency_p99_us')}us")
        if row.get("bulk_completed", 0) or row.get("bulk_shed", 0):
            print(f"  lanes: interactive completed={row.get('interactive_completed')} "
                  f"deadline_missed={row.get('interactive_deadline_missed')} "
                  f"p99={row.get('interactive_p99_us')}us | "
                  f"bulk completed={row.get('bulk_completed')} "
                  f"shed={row.get('bulk_shed')}")
        for expr in args.require:
            try:
                scope = {"__builtins__": {}, "len": len, "min": min,
                         "max": max, "abs": abs}
                ok = eval(expr, scope, dict(row))  # noqa: S307
            except Exception as e:
                raise SystemExit(f"{describe(row)}: cannot evaluate {expr!r}: {e}")
            mark = "ok" if ok else "FAIL"
            print(f"  require {expr!r}: {mark}")
            if not ok:
                failures.append((describe(row), expr))

    if failures:
        print(f"\nFAIL: {len(failures)} unsatisfied invariant(s):", file=sys.stderr)
        for where, expr in failures:
            print(f"  {where}: {expr}", file=sys.stderr)
        return 1
    print(f"\nOK: {len(rows)} row(s) satisfy {len(args.require)} invariant(s)")
    return 0


def check_ab(args):
    rows = {r.get("scenario"): r for r in load_rows(args.reports)}
    missing = [n for n in [args.adaptive, *args.extremes] if n not in rows]
    if missing:
        raise SystemExit(f"A/B rows missing from reports: {', '.join(missing)}")

    adaptive = rows[args.adaptive]
    a_rps = float(adaptive.get("throughput_rps", 0.0))
    if adaptive.get("batch_window") != "adaptive":
        raise SystemExit(
            f"{args.adaptive}: batch_window is {adaptive.get('batch_window')!r}, "
            "not 'adaptive' — the controller never ran")
    print(f"{args.adaptive:<20} {a_rps:>10.1f} req/s (window=adaptive)")

    failures = []
    for name in args.extremes:
        e_rps = float(rows[name].get("throughput_rps", 0.0))
        if e_rps <= 0.0:
            raise SystemExit(f"{name}: zero throughput — the extreme never served")
        ratio = a_rps / e_rps
        verdict = "OK" if ratio >= args.tolerance else "REGRESSED"
        print(f"{name:<20} {e_rps:>10.1f} req/s "
              f"(window={rows[name].get('batch_window')}) "
              f"adaptive/static = {ratio:.2f}x  {verdict}")
        if ratio < args.tolerance:
            failures.append((name, ratio))

    if failures:
        print(f"\nFAIL: adaptive window lost to {len(failures)} static extreme(s) "
              f"(tolerance {args.tolerance:.2f}x):", file=sys.stderr)
        for name, ratio in failures:
            print(f"  vs {name}: {ratio:.2f}x", file=sys.stderr)
        return 1
    print(f"\nOK: adaptive window >= {args.tolerance:.2f}x both static extremes")
    return 0


def check_saturation(args):
    if len(args.reports) != 1:
        raise SystemExit("saturation mode takes exactly one BENCH_saturation.json")
    with open(args.reports[0]) as f:
        surface = json.load(f)
    cells = surface.get("cells")
    if not isinstance(cells, list) or not cells:
        raise SystemExit(f"{args.reports[0]}: no cells in surface")

    failures = []
    for c in cells:
        label = (f"workers={c.get('workers')} shards={c.get('shards')} "
                 f"window={c.get('window_us')}us")
        problems = []
        if not c.get("knee_rps", 0) > 0:
            problems.append(f"knee_rps={c.get('knee_rps')}")
        if not c.get("submitted", 0) > 0:
            problems.append(f"submitted={c.get('submitted')}")
        if c.get("failed", 1) != 0:
            problems.append(f"failed={c.get('failed')}")
        status = "FAIL " + ", ".join(problems) if problems else "ok"
        print(f"{label:<40} knee {c.get('knee_rps', 0):>10.1f} req/s, "
              f"p99 {c.get('p99_at_knee_us')}us, "
              f"shed {c.get('shed_fraction', 0):.1%}  {status}")
        if problems:
            failures.append((label, problems))

    if len(cells) < args.min_cells:
        print(f"\nFAIL: only {len(cells)} cell(s), expected >= {args.min_cells}",
              file=sys.stderr)
        return 1
    if failures:
        print(f"\nFAIL: {len(failures)} unpopulated cell(s):", file=sys.stderr)
        for label, problems in failures:
            print(f"  {label}: {', '.join(problems)}", file=sys.stderr)
        return 1
    print(f"\nOK: all {len(cells)} cells populated (seed {surface.get('seed')}, "
          f"{surface.get('cell_seconds')}s per cell)")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="mode", required=True)

    rows = sub.add_parser("rows", help="assert invariants on capacity-report rows")
    rows.add_argument("reports", nargs="+")
    rows.add_argument("--scenario", help="only rows with this scenario name")
    rows.add_argument("--transport", help="only rows with this transport")
    rows.add_argument("--require", action="append", default=[],
                      help="expression over row columns that must be true "
                           "(repeatable)")
    rows.set_defaults(run=check_rows)

    ab = sub.add_parser("ab", help="adaptive batching vs static extremes")
    ab.add_argument("reports", nargs="+")
    ab.add_argument("--adaptive", required=True,
                    help="scenario name of the adaptive-window row")
    ab.add_argument("--extremes", nargs="+", required=True,
                    help="scenario names of the static-extreme rows")
    ab.add_argument("--tolerance", type=float, default=0.9,
                    help="minimum adaptive/static throughput ratio (default 0.9, "
                         "i.e. adaptive may trail an extreme by CI noise only)")
    ab.set_defaults(run=check_ab)

    sat = sub.add_parser("saturation", help="assert the sweep surface is populated")
    sat.add_argument("reports", nargs="+")
    sat.add_argument("--min-cells", type=int, default=8,
                     help="minimum number of grid cells (default 8)")
    sat.set_defaults(run=check_saturation)

    args = ap.parse_args()
    return args.run(args)


if __name__ == "__main__":
    sys.exit(main())
