#!/usr/bin/env python3
"""Bench-regression gate.

Compares a current bench report against a baseline (the previous
successful CI run's artifact when available, else the committed floors in
ci/) and fails if any row present in BOTH files has regressed in
throughput by more than the allowed fraction.

Two report shapes, selected with --mode:

* ``simulator`` (default): BENCH_simulator.json — rows keyed by their
  "bench" name, throughput read from "throughput". Committed floors live
  in ci/bench-baseline.json.
* ``coordinator``: BENCH_coordinator.json (the loadgen bench) — rows
  keyed by "scenario [transport]", throughput read from
  "throughput_rps". Committed floors live in ci/coordinator-baseline.json.
  Pass ``--only steady`` (comma-separated scenario names) to gate just
  the steady-state rows: the burst/chaos/failover scenarios shed load by
  design, so their req/s is a property of the shedding policy, not a
  regression signal.

Rows present on only one side are reported and skipped (new benches
appear, old ones retire — that is not a regression). Throughputs of 0 on
either side are skipped too (a unit-less placeholder row carries no
signal).

Usage: bench_gate.py BASELINE CURRENT [--max-regression 0.25]
                     [--mode coordinator] [--only steady]
"""

import argparse
import json
import sys


def load_rows(path, mode="simulator", only=None):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: expected a JSON array of bench rows")
    out = {}
    for row in rows:
        if mode == "coordinator":
            scenario = row.get("scenario")
            if not scenario or (only and scenario not in only):
                continue
            name = f"{scenario} [{row.get('transport', '?')}]"
            out[name] = float(row.get("throughput_rps", 0.0))
        else:
            name = row.get("bench")
            if name:
                out[name] = float(row.get("throughput", 0.0))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="maximum allowed fractional throughput drop (default 0.25)")
    ap.add_argument("--mode", choices=["simulator", "coordinator"],
                    default="simulator",
                    help="report shape: simulator bench rows (default) or "
                         "coordinator capacity-report rows")
    ap.add_argument("--only", default=None,
                    help="coordinator mode: comma-separated scenario names to "
                         "gate (default: every scenario in both files)")
    args = ap.parse_args()

    only = set(args.only.split(",")) if args.only else None
    base = load_rows(args.baseline, args.mode, only)
    cur = load_rows(args.current, args.mode, only)
    shared = sorted(set(base) & set(cur))
    if not shared:
        raise SystemExit("bench gate: no shared rows between baseline and current")

    floor = 1.0 - args.max_regression
    failures = []
    print(f"{'bench':<48} {'baseline':>14} {'current':>14} {'ratio':>7}")
    for name in shared:
        b, c = base[name], cur[name]
        if b <= 0.0 or c <= 0.0:
            print(f"{name:<48} {b:>14.1f} {c:>14.1f}   skip (no signal)")
            continue
        ratio = c / b
        verdict = "OK" if ratio >= floor else "REGRESSED"
        print(f"{name:<48} {b:>14.1f} {c:>14.1f} {ratio:>6.2f}x  {verdict}")
        if ratio < floor:
            failures.append((name, ratio))

    for name in sorted(set(base) ^ set(cur)):
        side = "baseline-only" if name in base else "new"
        print(f"{name:<48} ({side}; skipped)")

    if failures:
        print(f"\nFAIL: {len(failures)} row(s) regressed by more than "
              f"{args.max_regression:.0%}:", file=sys.stderr)
        for name, ratio in failures:
            print(f"  {name}: {ratio:.2f}x of baseline", file=sys.stderr)
        return 1
    print(f"\nOK: {len(shared)} shared row(s) within {args.max_regression:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
